"""Admission-control policy unit tests (DESIGN.md §13).

Pure host-side: no jax, no engine — the bounded priority queue,
validation, quarantine, displacement, deadline expiry and the
degradation ladder are all exercised directly so failures point at the
policy layer, not the serving stack above it.
"""

import numpy as np
import pytest

from repro.launch.admission import (STATUSES, AdmissionController,
                                    DegradationLadder, PriorityClass,
                                    ServeResult, Ticket)


def _ticket(adm, q, *, rid=0, cls=None, t=0.0):
    cls = cls or adm.resolve_class(None)
    return Ticket(rid, q, cls, t, t + cls.deadline_s, None,
                  adm.fingerprint(q))


# ---- validation ---------------------------------------------------------

def test_validate_accepts_finite_and_coerces():
    adm = AdmissionController(4)
    arr, reason = adm.validate([1, 2, 3, 4])
    assert reason == ""
    assert arr.dtype == np.float32 and arr.shape == (4,)


@pytest.mark.parametrize("bad", [
    np.full(4, np.nan, np.float32),
    np.array([1.0, np.inf, 0.0, 0.0], np.float32),
    np.zeros(3, np.float32),                      # wrong dim
    np.zeros((2, 4), np.float32),                 # wrong rank
    ["a", "b", "c", "d"],                         # not castable
])
def test_validate_rejects_poison(bad):
    adm = AdmissionController(4)
    arr, reason = adm.validate(bad)
    assert arr is None
    assert reason.startswith("poison:")


# ---- queue capacity / overload ------------------------------------------

def test_admit_fills_then_overloads_typed():
    adm = AdmissionController(3, queue_capacity=2)
    q = np.ones(3, np.float32)
    for i in range(2):
        verdict, displaced = adm.admit(_ticket(adm, q + i, rid=i, t=i))
        assert verdict is None and not displaced
    verdict, displaced = adm.admit(_ticket(adm, q + 9, rid=9, t=9.0))
    assert isinstance(verdict, ServeResult)
    assert verdict.status == "overloaded"
    assert "queue full" in verdict.reason
    assert not verdict.answered
    assert adm.depth == 2 and adm.stats()["overloaded"] == 1


def test_displacement_prefers_lowest_priority_youngest():
    hi = PriorityClass("hi", priority=0, sheddable=False)
    lo = PriorityClass("lo", priority=5)
    adm = AdmissionController(2, queue_capacity=2,
                              classes={"hi": hi, "lo": lo},
                              default_class="lo")
    q = np.ones(2, np.float32)
    adm.admit(_ticket(adm, q, rid=0, cls=lo, t=0.0))
    adm.admit(_ticket(adm, q + 1, rid=1, cls=lo, t=1.0))
    verdict, displaced = adm.admit(_ticket(adm, q + 2, rid=2, cls=hi,
                                           t=2.0))
    assert verdict is None
    assert len(displaced) == 1
    victim, vres = displaced[0]
    assert victim.req_id == 1          # youngest of the lowest priority
    assert vres.status == "overloaded" and "displaced" in vres.reason
    assert adm.depth == 2


def test_nonsheddable_never_displaced():
    hi = PriorityClass("hi", priority=0)
    lo = PriorityClass("lo", priority=5, sheddable=False)
    adm = AdmissionController(2, queue_capacity=1,
                              classes={"hi": hi, "lo": lo},
                              default_class="lo")
    q = np.ones(2, np.float32)
    adm.admit(_ticket(adm, q, rid=0, cls=lo))
    verdict, displaced = adm.admit(_ticket(adm, q + 1, rid=1, cls=hi,
                                           t=1.0))
    assert verdict is not None and verdict.status == "overloaded"
    assert not displaced and adm.depth == 1


def test_equal_priority_does_not_displace():
    adm = AdmissionController(2, queue_capacity=1)
    q = np.ones(2, np.float32)
    adm.admit(_ticket(adm, q, rid=0))
    verdict, displaced = adm.admit(_ticket(adm, q + 1, rid=1, t=1.0))
    assert verdict is not None and not displaced


# ---- batch assembly ------------------------------------------------------

def test_take_priority_then_fifo_order():
    hi = PriorityClass("hi", priority=0, deadline_ms=0)   # no deadline
    lo = PriorityClass("lo", priority=5, deadline_ms=0)
    adm = AdmissionController(2, queue_capacity=8,
                              classes={"hi": hi, "lo": lo},
                              default_class="lo")
    q = np.ones(2, np.float32)
    adm.admit(_ticket(adm, q, rid=0, cls=lo, t=0.0))
    adm.admit(_ticket(adm, q + 1, rid=1, cls=hi, t=1.0))
    adm.admit(_ticket(adm, q + 2, rid=2, cls=hi, t=2.0))
    batch, expired = adm.take(3.0, 8)
    assert [t.req_id for t in batch] == [1, 2, 0]
    assert not expired


def test_take_expires_past_deadline_as_typed_overloaded():
    cls = PriorityClass("default", deadline_ms=10.0)
    adm = AdmissionController(2, queue_capacity=8,
                              classes={"default": cls})
    q = np.ones(2, np.float32)
    adm.admit(_ticket(adm, q, rid=0, t=0.0))          # deadline at 0.010
    adm.admit(_ticket(adm, q + 1, rid=1, t=0.05))
    batch, expired = adm.take(0.051, 8)
    assert [t.req_id for t in batch] == [1]
    assert len(expired) == 1
    tk, res = expired[0]
    assert tk.req_id == 0
    assert res.status == "overloaded" and res.reason == "deadline"
    assert res.latency_s == pytest.approx(0.051)
    assert adm.stats()["expired_deadline"] == 1


def test_take_expire_false_serves_late_tickets():
    adm = AdmissionController(2, queue_capacity=8)
    q = np.ones(2, np.float32)
    adm.admit(_ticket(adm, q, rid=0, t=0.0))
    batch, expired = adm.take(1e9, 8, expire=False)
    assert [t.req_id for t in batch] == [0] and not expired


# ---- quarantine ----------------------------------------------------------

def test_quarantined_fingerprint_refused_at_admit():
    adm = AdmissionController(2, queue_capacity=8)
    q = np.ones(2, np.float32)
    fp = adm.fingerprint(q)
    adm.add_quarantine(fp, "dispatch failure")
    verdict, _ = adm.admit(_ticket(adm, q, rid=0))
    assert verdict.status == "rejected"
    assert "quarantined" in verdict.reason
    assert adm.stats()["rejected_quarantined"] == 1


def test_quarantine_is_bounded_lru():
    adm = AdmissionController(2, queue_capacity=8, quarantine_capacity=2)
    fps = [adm.fingerprint(np.full(2, float(i), np.float32))
           for i in range(3)]
    for fp in fps:
        adm.add_quarantine(fp, "x")
    assert adm.quarantined(fps[0]) is None        # evicted, oldest
    assert adm.quarantined(fps[2]) is not None


# ---- degradation ladder --------------------------------------------------

def test_ladder_floor_below_eps_raises():
    with pytest.raises(ValueError, match="cannot.*tighten"):
        DegradationLadder(0.3, 0.1)


def test_ladder_rungs_geometric_and_endpoints():
    lad = DegradationLadder(0.1, 0.4, rungs=3)
    assert lad.eps_values[0] == pytest.approx(0.1)
    assert lad.eps_values[-1] == pytest.approx(0.4)
    assert lad.eps_values == sorted(lad.eps_values)
    # geometric: constant ratio
    r = lad.eps_values[1] / lad.eps_values[0]
    assert lad.eps_values[2] / lad.eps_values[1] == pytest.approx(r)


def test_ladder_disabled_when_floor_equals_eps():
    lad = DegradationLadder(0.2, 0.2, rungs=5)
    assert lad.n_rungs == 1
    assert lad.rung(2.0) == 0


def test_ladder_rung_mapping_monotone():
    lad = DegradationLadder(0.1, 0.8, rungs=4, start=0.5)
    loads = [0.0, 0.3, 0.49, 0.5, 0.7, 0.9, 1.0, 3.0]
    rungs = [lad.rung(x) for x in loads]
    assert rungs[0] == 0 and rungs[2] == 0      # below start: full quality
    assert rungs[-1] == lad.n_rungs - 1         # saturated: the floor
    assert rungs == sorted(rungs)


# ---- results -------------------------------------------------------------

def test_serve_result_answered_property_matches_status_set():
    for s in STATUSES:
        assert ServeResult(status=s).answered == (s in ("ok", "degraded"))
