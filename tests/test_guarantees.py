"""Statistical (eps, delta) guarantee acceptance harness (ISSUE 5+7+8).

Nothing else in the repo tests the *contract itself* — only point
regressions.  Here we measure the empirical suboptimality-violation rate
over >= 200 seeded trials per configuration and require it to stay under
``delta`` plus a binomial confidence margin, for:

  * fp32 at the plan's ``eps``,
  * int8/int4 at the plan's honest ``eps_effective`` (DESIGN.md §10 —
    worst-case lattice bounds), pq at its *measured* bound (ISSUE 8:
    calibrated on the cell's own table, safety-inflated),
  * each with ``adaptive`` off and on (DESIGN.md §12 — early exit must
    not spend any extra failure probability),
  * plus the variance-aware 'bernstein' bound family,
  * across the ``pull_mode ∈ {row, coord, hybrid}`` axis for every
    precision tier (ISSUE 7/8, DESIGN.md §14): the coordinate estimator
    must honor the identical contract over its d_blocks-sized reward
    population, and a hybrid plan must agree exactly with whichever
    concrete mode `choose_pull_mode` selects.

The measured-error model itself is audited below
(`test_measured_bound_dominates_fresh_queries`): the safety-inflated
calibration bound must dominate the raw max error on fresh query draws
it never saw.

Deterministic: fixed data/key seeds, so this is tier-1 safe.  The
geometry is deliberately in the *non-saturated* regime (the last round
still samples a strict subset of the blocks) so the bandit genuinely
estimates — a fully-covered schedule would pass vacuously.
"""

import jax
import numpy as np
import pytest

from repro.core.boundedme_jax import (bounded_me_batched, choose_pull_mode,
                                      make_plan, measured_plan_quant_err)

# shared geometry: 128 blocks, 16 arm tiles, schedule never reaches full
# coverage (asserted below)
N_ARMS, DIM, BLOCK, K = 128, 8192, 64, 2
EPS, DELTA, VRANGE = 1.6, 0.2, 8.0
TRIALS = 200


def _instance(seed=0):
    rng = np.random.default_rng(seed)
    V = rng.normal(size=(N_ARMS, DIM)).astype(np.float32)
    Q = rng.normal(size=(TRIALS, DIM)).astype(np.float32)
    return V, Q


def _clustered_instance(seed=0, atoms=4, sigma=0.01):
    """A genuinely pq-compressible table: every 8-wide subspace chunk is
    a dictionary atom plus small noise.  Gaussian tables are
    incompressible — pq's measured error bound on them rightly consumes
    the whole budget and the schedule saturates, which would make the
    harness vacuous.  The pq cells therefore run in the regime product
    quantization exists for (clustered/low-entropy subspaces), where the
    measured bound is small and the bandit still genuinely samples."""
    rng = np.random.default_rng(seed)
    D = rng.normal(size=(atoms, 8)).astype(np.float32)
    idx = rng.integers(0, atoms, size=(N_ARMS, DIM // 8))
    V = (D[idx] + sigma * rng.normal(size=(N_ARMS, DIM // 8, 8))
         ).reshape(N_ARMS, DIM).astype(np.float32)
    Q = rng.normal(size=(TRIALS, DIM)).astype(np.float32)
    return V, Q


def _violation_rate(V, Q, ids, eps_budget):
    """Fraction of trials where the returned K arms are not eps-optimal.

    Trial b is a violation when, comparing the descending-sorted *true*
    mean products of the returned arms against the true top-K, any slot
    falls more than ``eps_budget`` short (the paper's suboptimality
    contract, at top-K rank granularity).
    """
    S = (V.astype(np.float64) @ Q.astype(np.float64).T).T / DIM  # (T, n)
    ids = np.asarray(ids)
    viols = 0
    for b in range(Q.shape[0]):
        true_top = np.sort(S[b])[::-1][:K]
        got = np.sort(S[b][ids[b]])[::-1]
        if np.any(true_top - got > eps_budget + 1e-7):
            viols += 1
    return viols / Q.shape[0]


def _margin(delta, trials):
    """Three-sigma binomial slack on an empirical rate at ``delta``."""
    return 3.0 * np.sqrt(delta * (1.0 - delta) / trials)


# full pull_mode x precision grid (ISSUE 7) on top of the ISSUE-5 axes;
# coord uses a 32-wide coordinate tile => 256 feature blocks, a larger
# without-replacement population than row's 128 wide blocks
@pytest.mark.parametrize("precision,adaptive,bound,pull_mode", [
    ("fp32", False, "hoeffding", "row"),
    ("fp32", True, "hoeffding", "row"),
    ("int8", False, "hoeffding", "row"),
    ("int8", True, "hoeffding", "row"),
    ("fp32", True, "bernstein", "row"),
    ("fp32", False, "hoeffding", "coord"),
    ("fp32", True, "hoeffding", "coord"),
    ("int8", False, "hoeffding", "coord"),
    ("fp32", True, "bernstein", "coord"),
    ("fp32", False, "hoeffding", "hybrid"),
    ("int8", False, "hoeffding", "hybrid"),
    # ISSUE 8: the sub-byte tiers through the identical contract — int4
    # under worst-case lattice bounds, pq under its measured bound
    ("int4", False, "hoeffding", "row"),
    ("int4", True, "hoeffding", "coord"),
    ("int4", False, "hoeffding", "hybrid"),
    ("pq", True, "hoeffding", "row"),
    ("pq", False, "hoeffding", "coord"),
    ("pq", True, "hoeffding", "hybrid"),
])
def test_empirical_violation_rate_within_delta(precision, adaptive, bound,
                                               pull_mode):
    V, Q = (_clustered_instance(seed=42) if precision == "pq"
            else _instance(seed=42))
    quant_err = None
    if precision == "pq":
        # calibrate on the cell's own table at every pull width the plan
        # might resolve to (hybrid measures both, keeps the max) — the
        # same recipe CascadeExecutor._build uses
        widths = {"row": (BLOCK,), "coord": (32,),
                  "hybrid": (BLOCK, 32)}[pull_mode]
        quant_err = max(measured_plan_quant_err(V, precision="pq", block=w)
                       for w in widths)
    # int4's worst-case lattice penalty (Q = 7 levels at VRANGE = 8) is
    # honest but wide: at EPS the widened schedule is driven to full
    # coverage, which would void the non-saturation teeth below.  The
    # int4 cells run at 2*EPS — still well inside the regime where the
    # violation contract has bite.
    eps = 2 * EPS if precision == "int4" else EPS
    plan = make_plan(N_ARMS, DIM, K=K, eps=eps, delta=DELTA,
                     value_range=VRANGE, block=BLOCK, precision=precision,
                     bound=bound, pull_mode=pull_mode, coord_block=32,
                     quant_err=quant_err)
    # the harness must have teeth: the schedule still *samples*
    assert plan.schedule.rounds[-1].t_cum < plan.n_blocks
    keys = jax.random.split(jax.random.PRNGKey(7), TRIALS)
    out = bounded_me_batched(V, Q, keys, plan=plan, final_exact=True,
                             use_pallas=False, adaptive=adaptive)
    ids = out[0]
    rate = _violation_rate(V, Q, ids, plan.eps_effective)
    assert rate <= DELTA + _margin(DELTA, TRIALS), (
        f"{precision}/adaptive={adaptive}/{bound}/{pull_mode}: "
        f"violation rate {rate}")
    if adaptive:
        rounds = np.asarray(out[2])
        n_rounds = len(plan.schedule.rounds)
        assert rounds.shape == (TRIALS,)
        assert np.all((rounds >= 1) & (rounds <= n_rounds))


@pytest.mark.parametrize("precision", ["fp32", "int8"])
def test_hybrid_agrees_with_its_selected_mode(precision):
    """A hybrid plan IS the winner's plan — same schedule, same geometry,
    same answers — so its guarantee inherits from the concrete mode's
    harness run above, by identity rather than by re-measurement."""
    kw = dict(K=K, eps=EPS, delta=DELTA, value_range=VRANGE, block=BLOCK,
              precision=precision, coord_block=32)
    hyb = make_plan(N_ARMS, DIM, pull_mode="hybrid", **kw)
    row = make_plan(N_ARMS, DIM, pull_mode="row", **kw)
    coord = make_plan(N_ARMS, DIM, pull_mode="coord", **kw)
    assert hyb.pull_mode in ("row", "coord")
    assert hyb.pull_mode == choose_pull_mode(row, coord)
    assert hyb == (row if hyb.pull_mode == "row" else coord)
    # the dispatcher's contract: never >10% worse than the better mode
    best = min(row.total_multiplies, coord.total_multiplies)
    assert hyb.total_multiplies <= 1.10 * best
    # and the answers are literally the winner's answers
    V, Q = _instance(seed=11)
    keys = jax.random.split(jax.random.PRNGKey(5), 16)
    win = row if hyb.pull_mode == "row" else coord
    ids_h, sc_h = bounded_me_batched(V, Q[:16], keys, plan=hyb,
                                     final_exact=True, use_pallas=False)
    ids_w, sc_w = bounded_me_batched(V, Q[:16], keys, plan=win,
                                     final_exact=True, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(ids_h), np.asarray(ids_w))
    np.testing.assert_array_equal(np.asarray(sc_h), np.asarray(sc_w))


def test_int8_eps_effective_is_the_honest_budget():
    """Every quantized plan must audit its own quantization penalty:
    eps_effective >= eps, collapsing to eps exactly when quant_err is 0;
    the coarser int4 lattice must admit a larger worst-case penalty than
    int8's (ISSUE 8)."""
    p8 = make_plan(N_ARMS, DIM, K=K, eps=EPS, delta=DELTA,
                   value_range=VRANGE, block=BLOCK, precision="int8")
    p4 = make_plan(N_ARMS, DIM, K=K, eps=EPS, delta=DELTA,
                   value_range=VRANGE, block=BLOCK, precision="int4")
    p32 = make_plan(N_ARMS, DIM, K=K, eps=EPS, delta=DELTA,
                    value_range=VRANGE, block=BLOCK)
    assert p8.quant_err > 0.0
    assert p8.eps_effective >= EPS
    assert p4.quant_err > p8.quant_err          # 7 levels vs 127
    # (eps_effective only exceeds eps once some round's eps_l dips below
    # 2*quant_err — at this geometry both lattice tiers still absorb
    # their bias by sampling, so the budgets coincide at eps exactly)
    assert p4.eps_effective >= p8.eps_effective >= EPS
    assert p32.eps_effective == EPS


@pytest.mark.parametrize("precision", ["int8", "int4", "pq"])
def test_measured_bound_dominates_fresh_queries(precision):
    """The measured error model's conservativeness audit (ISSUE 8,
    DESIGN.md §10): the safety-inflated bound calibrated on 32 queries
    must dominate the raw (safety=1) max per-pull error observed on 100
    *fresh* query draws the calibration never saw — i.e. the 2x safety
    factor genuinely covers sampling variation of the max statistic."""
    V, _ = _instance(seed=42)
    bound = measured_plan_quant_err(V, precision=precision, block=BLOCK)
    fresh = measured_plan_quant_err(V, precision=precision, block=BLOCK,
                                    n_queries=100, seed=1234, safety=1.0)
    assert 0.0 < fresh <= bound, (precision, fresh, bound)


def test_adaptive_certified_exits_are_sound_on_easy_stream():
    """On a stream with planted easy winners, adaptive certifies early on
    most queries AND the certified answers are exactly right — the
    union-bound argument of DESIGN.md §12 in empirical form."""
    rng = np.random.default_rng(3)
    V = rng.normal(size=(N_ARMS, DIM)).astype(np.float32)
    Q = rng.normal(size=(64, DIM)).astype(np.float32)
    # every query's winner is its own self-similar row (score ~ 1 vs the
    # ~ 1/sqrt(DIM) noise scores), spread across tiles
    winners = (np.arange(64) * 13) % N_ARMS
    for b, w in enumerate(winners):
        V[w] = Q[b]
    plan = make_plan(N_ARMS, DIM, K=1, eps=EPS, delta=DELTA,
                     value_range=VRANGE, block=BLOCK)
    keys = jax.random.split(jax.random.PRNGKey(9), 64)
    ids, _, rounds = bounded_me_batched(V, Q, keys, plan=plan,
                                        final_exact=True, use_pallas=False,
                                        adaptive=True)
    ids = np.asarray(ids)[:, 0]
    rounds = np.asarray(rounds)
    n_rounds = len(plan.schedule.rounds)
    S = (V.astype(np.float64) @ Q.astype(np.float64).T).T / DIM
    truth = np.argmax(S, axis=1)
    early = rounds < n_rounds
    assert early.mean() > 0.5              # the stream is genuinely easy
    # certified-early answers are exact, not merely eps-close
    assert np.all(ids[early] == truth[early])


def test_multi_tenant_violation_rates_within_delta():
    """ISSUE 10: the (eps, delta) contract survives multi-tenant
    scheduling.  Two tenants with *different* eps and precision served
    through ONE `MultiTenantRuntime` — sharing the scheduler, executor
    cache and device pool — must each keep their own empirical
    violation rate within delta + 3 sigma over TRIALS seeded trials,
    measured against their own plan's honest ``eps_effective`` (the
    same statistic as the single-plan cells above)."""
    from repro.launch.tenancy import (MultiTenantRuntime, TableRegistry,
                                      TenantConfig)
    VA, QA = _instance(seed=42)
    VB, QB = _instance(seed=43)
    tenants = {
        "a": (VA, QA, TenantConfig(
            K=K, eps=EPS, delta=DELTA, precision="fp32",
            value_range=VRANGE, block=BLOCK, deadline_ms=0.0,
            queue_capacity=256, seed=1)),
        "b": (VB, QB, TenantConfig(
            K=K, eps=1.25 * EPS, delta=DELTA, precision="int8",
            value_range=VRANGE, block=BLOCK, deadline_ms=0.0,
            queue_capacity=256, seed=2)),
    }
    reg = TableRegistry(lanes=8)
    for name, (V, _, cfg) in tenants.items():
        reg.register(name, V, cfg)
    mt = MultiTenantRuntime(reg, batch_wait_ms=1.0)
    mt.warmup()
    rids = {name: [] for name in tenants}
    for i in range(TRIALS):
        for name, (_, Q, _cfg) in tenants.items():
            rids[name].append(mt.submit(Q[i], tenant=name, now=i * 1e-3))
        if (i + 1) % 64 == 0:
            mt.drain(now=1.0 + i)
    mt.drain(now=1e6)
    for name, (V, Q, _cfg) in tenants.items():
        plan = reg.executors(name)[0][0].plan
        # the harness must have teeth: this tenant's schedule samples
        assert plan.schedule.rounds[-1].t_cum < plan.n_blocks
        results = [mt.result(r) for r in rids[name]]
        assert all(r is not None and r.status == "ok" for r in results)
        ids = np.stack([r.ids for r in results])
        rate = _violation_rate(V, Q, ids, plan.eps_effective)
        assert rate <= DELTA + _margin(DELTA, TRIALS), (
            f"tenant {name}: violation rate {rate}")