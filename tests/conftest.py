import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running test")


class _FakeStrategies:
    """Stands in for hypothesis.strategies when hypothesis is absent: any
    strategy constructor returns None (the @given stub ignores them)."""

    def __getattr__(self, name):
        return lambda *a, **k: None


def optional_hypothesis():
    """Returns ``(given, settings, st)``.

    With hypothesis installed these are the real decorators; without it the
    property tests are collected but individually *skipped* (instead of the
    pre-PR-1 behaviour, where the bare import failed the whole module's
    collection and took every plain unit test in it down too).  Install the
    pinned dev deps with ``pip install -r requirements-dev.txt``.
    """
    try:
        from hypothesis import given, settings, strategies as st
        return given, settings, st
    except ImportError:
        def given(*a, **k):
            def deco(fn):
                import functools

                @functools.wraps(fn)
                def stub(*args, **kwargs):
                    pass
                return pytest.mark.skip(
                    reason="hypothesis not installed "
                           "(pip install -r requirements-dev.txt)")(stub)
            return deco

        def settings(*a, **k):
            return lambda fn: fn

        return given, settings, _FakeStrategies()
