"""Cross-shard merge correctness of `sharded_bounded_me_decode` (ISSUE 2).

Run on 2 fake CPU devices in a subprocess so the main pytest process keeps
its 1-device view (per the dry-run isolation rule).  The CI workflow also
exports ``XLA_FLAGS=--xla_force_host_platform_device_count=2`` and runs
this file directly; the preamble honours an outer flag so both paths work.
"""

import os
import subprocess
import sys

import pytest

_ENV_CODE_PREAMBLE = r"""
import os
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=2").strip()
import jax, jax.numpy as jnp, numpy as np
"""


def _run(code: str, timeout=480):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    r = subprocess.run([sys.executable, "-c", _ENV_CODE_PREAMBLE + code],
                       env=env, capture_output=True, text=True,
                       timeout=timeout)
    assert "OK" in r.stdout, r.stdout + "\n" + r.stderr


@pytest.mark.slow
def test_sharded_decode_bit_exact_vs_single_device():
    """2-device sharded top-K == single-device fused-path top-K, bitwise.

    The single-device jnp decode path is bit-identical to the fused kernel
    (tests/test_boundedme_decode.py), so comparing against it transitively
    pins the sharded merge to the fused path.
    """
    _run(r"""
from repro.core.boundedme_jax import bounded_me_decode, make_plan
from repro.distributed.sharding import sharded_bounded_me_decode
mesh = jax.make_mesh((2,), ("model",))
rng = np.random.default_rng(0)
n, N, B, K = 512, 1024, 3, 3
V = jnp.asarray(rng.normal(size=(n, N)), jnp.float32)
Q = jnp.asarray(rng.normal(size=(B, N)), jnp.float32)
key = jax.random.PRNGKey(7)
plan = make_plan(n, N, K=K, eps=1e-4, delta=0.05, value_range=8.0, block=128)
i1, s1 = bounded_me_decode(V, Q, key, plan=plan, final_exact=True,
                           use_pallas=False)
i2, s2, gaps = sharded_bounded_me_decode(
    V, Q, key, mesh=mesh, K=K, eps=1e-4, delta=0.05, value_range=8.0,
    block=128)
np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))  # bit-exact
truth = np.argsort(-(np.asarray(V) @ np.asarray(Q).T), axis=0)[:K].T
np.testing.assert_array_equal(np.asarray(i2), truth)
assert np.all(np.asarray(gaps) > 0)       # winners beat their threshold
print("OK")
""")


@pytest.mark.slow
def test_sharded_decode_ragged_n():
    """n % shards != 0: zero pad rows must never win, results stay exact."""
    _run(r"""
from repro.core.boundedme_jax import bounded_me_decode, make_plan
from repro.distributed.sharding import make_shard_plan, \
    sharded_bounded_me_decode
mesh = jax.make_mesh((2,), ("model",))
rng = np.random.default_rng(1)
n, N, B, K = 501, 768, 2, 4
# all-negative table: zero padding rows (score 0) would win any merge that
# forgets to mask them
V = jnp.asarray(-np.abs(rng.normal(size=(n, N))), jnp.float32)
Q = jnp.asarray(np.abs(rng.normal(size=(B, N))), jnp.float32)
key = jax.random.PRNGKey(3)
plan, n_local, n_pad, k_out = make_shard_plan(n, N, 2, K=K, eps=1e-4,
                                              delta=0.05, value_range=8.0,
                                              block=128)
assert n_pad == 1 and n_local == 251, (n_local, n_pad)
assert plan.K == K    # padding is masked in-cascade, K is not inflated
i1, s1 = bounded_me_decode(V, Q, key,
                           plan=make_plan(n, N, K=K, eps=1e-4, delta=0.05,
                                          value_range=8.0, block=128),
                           final_exact=True, use_pallas=False)
i2, s2, _ = sharded_bounded_me_decode(
    V, Q, key, mesh=mesh, K=K, eps=1e-4, delta=0.05, value_range=8.0,
    block=128)
assert int(np.asarray(i2).max()) < n      # no padding id leaked
np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
print("OK")
""")


@pytest.mark.slow
def test_sharded_decode_candidates_and_gaps():
    """Per-shard candidate sets: shapes, exactness, and gap semantics."""
    _run(r"""
from repro.distributed.sharding import make_shard_plan, \
    sharded_bounded_me_decode
mesh = jax.make_mesh((2,), ("model",))
rng = np.random.default_rng(2)
n, N, B, K = 256, 512, 2, 2
V = jnp.asarray(rng.normal(size=(n, N)), jnp.float32)
Q = jnp.asarray(rng.normal(size=(B, N)), jnp.float32)
plan, n_local, n_pad, k_out = make_shard_plan(n, N, 2, K=K, eps=1e-4,
                                              delta=0.05, value_range=8.0,
                                              block=128)
ids, sc, gaps, cands = sharded_bounded_me_decode(
    V, Q, jax.random.PRNGKey(0), mesh=mesh, K=K, eps=1e-4, delta=0.05,
    value_range=8.0, block=128, return_candidates=True)
assert cands["ids"].shape == (B, 2, k_out), cands["ids"].shape
# every candidate's reported score is the exact mean product
Vn, Qn = np.asarray(V), np.asarray(Q)
cid = np.asarray(cands["ids"]); csc = np.asarray(cands["scores"])
for b in range(B):
    for s in range(2):
        for j in range(k_out):
            exact = float(Vn[cid[b, s, j]] @ Qn[b]) / N
            assert abs(csc[b, s, j] - exact) < 1e-6, (b, s, j)
# gaps: candidate score minus the shard's (K_local+1)-th candidate score
cg = np.asarray(cands["gaps"])
np.testing.assert_allclose(cg, csc - csc[:, :, -1:], rtol=1e-6, atol=1e-7)
assert np.all(np.asarray(gaps) >= 0)
print("OK")
""")


@pytest.mark.slow
def test_caller_padded_vocab_masked_in_cascade():
    """Adversarial vocab padding (rows that out-score every real arm) must
    be masked inside each shard's cascade, not just at the merge."""
    _run(r"""
from repro.distributed.sharding import sharded_bounded_me_decode
mesh = jax.make_mesh((2,), ("model",))
rng = np.random.default_rng(6)
n, n_valid, N, B, K = 512, 450, 512, 2, 3
V = np.asarray(rng.normal(size=(n, N)), np.float32)
V[n_valid:] = 100.0           # caller padding rows dominate positive queries
Q = jnp.asarray(np.abs(rng.normal(size=(B, N))), jnp.float32)
ids, sc, _ = sharded_bounded_me_decode(
    jnp.asarray(V), Q, jax.random.PRNGKey(1), mesh=mesh, K=K,
    n_valid=n_valid, eps=1e-4, delta=0.05, value_range=8.0, block=128)
assert int(np.asarray(ids).max()) < n_valid, np.asarray(ids)
truth = np.argsort(-(V[:n_valid] @ np.asarray(Q).T), axis=0)[:K].T
np.testing.assert_array_equal(np.asarray(ids), truth)
print("OK")
""")


@pytest.mark.slow
def test_final_exact_false_still_merges_exactly():
    """With final_exact=False the merge must rescore candidates exactly."""
    _run(r"""
from repro.distributed.sharding import sharded_bounded_me_decode
mesh = jax.make_mesh((2,), ("model",))
rng = np.random.default_rng(4)
n, N, B, K = 512, 512, 2, 3
V = jnp.asarray(rng.normal(size=(n, N)), jnp.float32)
Q = jnp.asarray(rng.normal(size=(B, N)), jnp.float32)
ids, sc, _ = sharded_bounded_me_decode(
    V, Q, jax.random.PRNGKey(5), mesh=mesh, K=K, eps=1e-4, delta=0.05,
    value_range=8.0, block=128, final_exact=False)
truth = np.argsort(-(np.asarray(V) @ np.asarray(Q).T), axis=0)[:K].T
np.testing.assert_array_equal(np.asarray(ids), truth)
# scores are the dense-rescore exact products, not block-mean estimates
Vn, Qn = np.asarray(V), np.asarray(Q)
for b in range(B):
    for j in range(K):
        exact = float(Vn[np.asarray(ids)[b, j]] @ Qn[b]) / N
        assert abs(float(np.asarray(sc)[b, j]) - exact) < 1e-6
print("OK")
""")


@pytest.mark.slow
def test_sharded_int8_parity_with_single_device():
    """int8 sharded decode == int8 single-device decode == exact truth.

    Quantization is shard-local (per-tile scales over each shard's own
    rows) and the per-shard plans widen their bounds independently, so
    parity is asserted at the result level: with winner margins above the
    int8 bias both paths must return the identical exact-rescored top-K.
    """
    _run(r"""
from repro.core.boundedme_jax import bounded_me_decode, make_plan
from repro.distributed.sharding import sharded_bounded_me_decode
mesh = jax.make_mesh((2,), ("model",))
rng = np.random.default_rng(11)
n, N, B, K = 512, 1024, 3, 3
V = (0.2 * rng.normal(size=(n, N))).astype(np.float32)
Q = rng.normal(size=(B, N)).astype(np.float32)
for b in range(B):        # planted winners with margins >> the int8 bias
    unit = Q[b] / np.linalg.norm(Q[b])
    for j in range(K):
        V[31 * b + 5 * j] = (4.0 + 0.5 * j) * unit
V = jnp.asarray(V); Q = jnp.asarray(Q)
key = jax.random.PRNGKey(7)
plan = make_plan(n, N, K=K, eps=1e-3, delta=0.05, value_range=8.0,
                 block=128, precision="int8")
i1, s1 = bounded_me_decode(V, Q, key, plan=plan, final_exact=True,
                           use_pallas=False)
i2, s2, gaps = sharded_bounded_me_decode(
    V, Q, key, mesh=mesh, K=K, eps=1e-3, delta=0.05, value_range=8.0,
    block=128, precision="int8")
truth = np.argsort(-(np.asarray(V) @ np.asarray(Q).T), axis=0)[:K].T
np.testing.assert_array_equal(np.asarray(i1), truth)
np.testing.assert_array_equal(np.asarray(i2), truth)
# both paths rescore candidates in fp32: scores agree to fp32 tolerance
np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                           rtol=1e-5, atol=1e-6)
assert np.all(np.asarray(gaps) > 0)
print("OK")
""")


@pytest.mark.slow
def test_serve_engine_sharded_end_to_end():
    """MIPSServeEngine over a 2-device mesh: recall 1.0 at tiny eps."""
    _run(r"""
from repro.launch.mesh import make_serving_mesh
from repro.launch.serve import MIPSServeEngine, simulate_stream
mesh = make_serving_mesh()
assert mesh is not None and mesh.shape["model"] == 2
rng = np.random.default_rng(0)
table = rng.normal(size=(501, 256)).astype(np.float32)   # ragged on 2
eng = MIPSServeEngine(table, K=3, eps=1e-4, delta=0.05, value_range=8.0,
                      block=128, batch_size=4, deadline_ms=1.0, mesh=mesh,
                      recall_sample_rate=1.0)
qs = rng.normal(size=(24, 256)).astype(np.float32)
stats = simulate_stream(eng, qs, interarrival_ms=0.05)
assert stats["completed"] == 24 and stats["pending"] == 0, stats
assert stats["recall"]["mean"] == 1.0, stats["recall"]
print("OK")
""")
