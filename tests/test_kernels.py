"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Shape/dtype sweeps per the deliverable: each kernel asserted allclose
against ref.py across tile geometries and dtypes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.gather_dot import gather_block_dot_pallas
from repro.kernels.blocked_matvec import blocked_matvec_pallas


def _v4(n_tiles, n_blocks, R, C, dtype, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n_tiles, n_blocks, R, C)).astype(dtype)


class TestGatherBlockDot:
    @pytest.mark.parametrize("R,C", [(8, 128), (8, 512), (4, 256), (16, 128)])
    @pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
    def test_allclose_vs_ref(self, R, C, dtype):
        rng = np.random.default_rng(1)
        V4 = jnp.asarray(_v4(12, 10, R, C, np.float32)).astype(dtype)
        idx = jnp.asarray(rng.permutation(12)[:5], jnp.int32)
        cols = jnp.asarray(rng.permutation(10)[:4], jnp.int32)
        qsel = jnp.asarray(rng.normal(size=(4, C)), dtype)
        out = gather_block_dot_pallas(V4, idx, cols, qsel, interpret=True)
        exp = ref.gather_block_dot_ref(V4, idx, cols, qsel)
        tol = 1e-5 if dtype == np.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=tol, atol=tol)
        assert out.dtype == jnp.float32  # f32 accumulation always

    def test_single_block_single_tile(self):
        V4 = jnp.asarray(_v4(1, 1, 8, 128, np.float32))
        idx = jnp.zeros((1,), jnp.int32)
        cols = jnp.zeros((1,), jnp.int32)
        qsel = jnp.ones((1, 128), jnp.float32)
        out = gather_block_dot_pallas(V4, idx, cols, qsel, interpret=True)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(V4[0, 0].sum(-1))[None],
                                   rtol=1e-5)

    def test_duplicate_gather_indices(self):
        """The same tile/block may be addressed twice (stress index_map)."""
        V4 = jnp.asarray(_v4(4, 4, 8, 128, np.float32))
        idx = jnp.asarray([2, 2, 0], jnp.int32)
        cols = jnp.asarray([1, 1], jnp.int32)
        qsel = jnp.asarray(np.random.default_rng(0).normal(size=(2, 128)),
                           jnp.float32)
        out = gather_block_dot_pallas(V4, idx, cols, qsel, interpret=True)
        exp = ref.gather_block_dot_ref(V4, idx, cols, qsel)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-5)


class TestBlockedMatvec:
    @pytest.mark.parametrize("n,d,tn,td", [(512, 1024, 256, 512),
                                           (256, 512, 128, 128),
                                           (1024, 2048, 256, 1024)])
    @pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
    def test_allclose_vs_ref(self, n, d, tn, td, dtype):
        rng = np.random.default_rng(2)
        W = jnp.asarray(rng.normal(size=(n, d)), dtype)
        q = jnp.asarray(rng.normal(size=d), dtype)
        out = blocked_matvec_pallas(W, q, tile_n=tn, tile_d=td,
                                    interpret=True)
        exp = ref.blocked_matvec_ref(W, q)
        tol = 1e-4 if dtype == np.float32 else 0.3
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=tol, atol=tol)

    def test_indivisible_raises(self):
        W = jnp.zeros((100, 512))
        q = jnp.zeros((512,))
        with pytest.raises(ValueError):
            blocked_matvec_pallas(W, q, tile_n=64, tile_d=512,
                                  interpret=True)


def test_ops_wrappers_dispatch_interpret_on_cpu():
    assert not ops.on_tpu()
    V4 = jnp.asarray(_v4(2, 2, 8, 128, np.float32))
    out = ops.gather_block_dot(V4, jnp.zeros((1,), jnp.int32),
                               jnp.zeros((1,), jnp.int32),
                               jnp.ones((1, 128)))
    assert out.shape == (1, 8)
