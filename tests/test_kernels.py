"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Shape/dtype sweeps per the deliverable: each kernel asserted allclose
against ref.py across tile geometries and dtypes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.gather_dot import gather_block_dot_pallas
from repro.kernels.blocked_matvec import blocked_matvec_pallas


def _v4(n_tiles, n_blocks, R, C, dtype, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n_tiles, n_blocks, R, C)).astype(dtype)


class TestGatherBlockDot:
    @pytest.mark.parametrize("R,C", [(8, 128), (8, 512), (4, 256), (16, 128)])
    @pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
    def test_allclose_vs_ref(self, R, C, dtype):
        rng = np.random.default_rng(1)
        V4 = jnp.asarray(_v4(12, 10, R, C, np.float32)).astype(dtype)
        idx = jnp.asarray(rng.permutation(12)[:5], jnp.int32)
        cols = jnp.asarray(rng.permutation(10)[:4], jnp.int32)
        qsel = jnp.asarray(rng.normal(size=(4, C)), dtype)
        out = gather_block_dot_pallas(V4, idx, cols, qsel, interpret=True)
        exp = ref.gather_block_dot_ref(V4, idx, cols, qsel)
        # f32 tol leaves headroom for accumulation-order differences between
        # the kernel's per-block adds and the fused einsum contraction
        tol = 1e-4 if dtype == np.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=tol, atol=tol)
        assert out.dtype == jnp.float32  # f32 accumulation always

    def test_single_block_single_tile(self):
        V4 = jnp.asarray(_v4(1, 1, 8, 128, np.float32))
        idx = jnp.zeros((1,), jnp.int32)
        cols = jnp.zeros((1,), jnp.int32)
        qsel = jnp.ones((1, 128), jnp.float32)
        out = gather_block_dot_pallas(V4, idx, cols, qsel, interpret=True)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(V4[0, 0].sum(-1))[None],
                                   rtol=1e-5)

    def test_duplicate_gather_indices(self):
        """The same tile/block may be addressed twice (stress index_map)."""
        V4 = jnp.asarray(_v4(4, 4, 8, 128, np.float32))
        idx = jnp.asarray([2, 2, 0], jnp.int32)
        cols = jnp.asarray([1, 1], jnp.int32)
        qsel = jnp.asarray(np.random.default_rng(0).normal(size=(2, 128)),
                           jnp.float32)
        out = gather_block_dot_pallas(V4, idx, cols, qsel, interpret=True)
        exp = ref.gather_block_dot_ref(V4, idx, cols, qsel)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-5)


class TestBlockedMatvec:
    @pytest.mark.parametrize("n,d,tn,td", [(512, 1024, 256, 512),
                                           (256, 512, 128, 128),
                                           (1024, 2048, 256, 1024)])
    @pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
    def test_allclose_vs_ref(self, n, d, tn, td, dtype):
        rng = np.random.default_rng(2)
        W = jnp.asarray(rng.normal(size=(n, d)), dtype)
        q = jnp.asarray(rng.normal(size=d), dtype)
        out = blocked_matvec_pallas(W, q, tile_n=tn, tile_d=td,
                                    interpret=True)
        exp = ref.blocked_matvec_ref(W, q)
        tol = 1e-4 if dtype == np.float32 else 0.3
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   rtol=tol, atol=tol)

    def test_indivisible_raises(self):
        W = jnp.zeros((100, 512))
        q = jnp.zeros((512,))
        with pytest.raises(ValueError):
            blocked_matvec_pallas(W, q, tile_n=64, tile_d=512,
                                  interpret=True)


def _fused_setup(n, N, K, tile, block, eps=0.2, seed=0, final_exact=False):
    """Pad + tile a random instance and flatten its schedule."""
    from repro.core.boundedme_jax import (_pad_operands, _tile_major,
                                          make_plan)
    from repro.core.schedule import flatten_schedule

    rng = np.random.default_rng(seed)
    V = rng.normal(size=(n, N)).astype(np.float32)
    q = rng.normal(size=N).astype(np.float32)
    plan = make_plan(n, N, K=K, eps=eps, delta=0.1, value_range=8.0,
                     tile=tile, block=block)
    Vp, qp = _pad_operands(jnp.asarray(V), jnp.asarray(q), plan)
    V4 = _tile_major(Vp, plan)
    qb = qp.reshape(plan.n_blocks, plan.block)
    perm = jax.random.permutation(jax.random.PRNGKey(seed), plan.n_blocks)
    flat = flatten_schedule(plan.schedule, final_coverage=final_exact)
    cols = np.asarray(perm)[flat.bpos]
    return V, q, plan, V4, qb, flat, cols


class TestFusedCascade:
    """The single-dispatch cascade kernel vs the step-accurate oracle."""

    @pytest.mark.parametrize("n,N,K,tile,block", [
        (512, 2048, 3, 8, 128),      # aligned
        (517, 2100, 3, 8, 256),      # ragged: n % tile != 0, N % block != 0
        (123, 300, 12, 8, 64),       # K > tile with ragged everything
        (64, 4096, 2, 4, 512),       # tall blocks, few tiles
    ])
    @pytest.mark.parametrize("final_exact", [False, True])
    def test_parity_vs_oracle(self, n, N, K, tile, block, final_exact):
        from repro.kernels.fused_cascade import fused_cascade_pallas

        _, _, plan, V4, qb, flat, cols = _fused_setup(
            n, N, K, tile, block, final_exact=final_exact)
        slotcode, rmeta = flat.packed()
        ids_k, vals_k = fused_cascade_pallas(
            V4, qb, jnp.asarray(slotcode), jnp.asarray(rmeta),
            jnp.asarray(cols), n_arms=plan.n, K=plan.K,
            t_final=flat.t_final, n_final=flat.n_final, interpret=True)
        ids_o, vals_o = ref.fused_cascade_ref(V4, qb, flat, cols,
                                              n_arms=plan.n, K=plan.K)
        np.testing.assert_array_equal(np.asarray(ids_k), ids_o)
        np.testing.assert_allclose(np.asarray(vals_k), vals_o,
                                   rtol=2e-5, atol=1e-6)

    def test_multiple_rounds_still_one_dispatch(self):
        """The acceptance check: dispatch count is 1 regardless of rounds."""
        from repro.core.boundedme_jax import _run_blocked, make_plan

        plan = make_plan(512, 2048, K=3, eps=0.3, delta=0.1, value_range=8.0,
                         tile=8, block=128)
        assert len(plan.schedule.rounds) >= 3  # a real multi-round cascade

        rng = np.random.default_rng(0)
        V = jnp.asarray(rng.normal(size=(512, 2048)), jnp.float32)
        q = jnp.asarray(rng.normal(size=2048), jnp.float32)

        def fused(V, q, k):
            return _run_blocked(V, q, k, plan=plan, use_pallas=True)

        jaxpr = jax.make_jaxpr(fused)(V, q, jax.random.PRNGKey(0))
        assert ops.count_pallas_calls(jaxpr.jaxpr) == 1

    def test_batched_kernel_matches_loop_of_singles(self):
        from repro.kernels.fused_cascade import (fused_cascade_batched_pallas,
                                                 fused_cascade_pallas)
        from repro.core.boundedme_jax import _pad_operands, _tile_major, \
            make_plan
        from repro.core.schedule import flatten_schedule

        rng = np.random.default_rng(3)
        n, N, B = 256, 1024, 3
        V = rng.normal(size=(n, N)).astype(np.float32)
        Q = rng.normal(size=(B, N)).astype(np.float32)
        plan = make_plan(n, N, K=2, eps=0.2, delta=0.1, value_range=8.0,
                         block=128)
        Vp, Qp = _pad_operands(jnp.asarray(V), jnp.asarray(Q), plan)
        V4 = _tile_major(Vp, plan)
        Qb = Qp.reshape(B, plan.n_blocks, plan.block)
        keys = jax.random.split(jax.random.PRNGKey(1), B)
        perms = jax.vmap(
            lambda k: jax.random.permutation(k, plan.n_blocks))(keys)
        flat = flatten_schedule(plan.schedule)
        slotcode, rmeta = flat.packed()
        cols = jnp.take(perms, jnp.asarray(flat.bpos), axis=1)
        kw = dict(n_arms=plan.n, K=plan.K, t_final=flat.t_final,
                  n_final=flat.n_final, interpret=True)
        ids_b, vals_b = fused_cascade_batched_pallas(
            V4, Qb, jnp.asarray(slotcode), jnp.asarray(rmeta), cols, **kw)
        for b in range(B):
            ids_s, vals_s = fused_cascade_pallas(
                V4, Qb[b], jnp.asarray(slotcode), jnp.asarray(rmeta),
                cols[b], **kw)
            np.testing.assert_array_equal(np.asarray(ids_b[b]),
                                          np.asarray(ids_s))
            np.testing.assert_array_equal(np.asarray(vals_b[b]),
                                          np.asarray(vals_s))

    def test_saturated_rounds_no_pull_steps(self):
        """Tiny n_blocks saturates t at N: rounds with t_new == 0 still
        eliminate (no-pull steps carry the round-end flag)."""
        from repro.kernels.fused_cascade import fused_cascade_pallas

        _, _, plan, V4, qb, flat, cols = _fused_setup(400, 256, 1, 8, 64,
                                                      eps=0.05, seed=5)
        assert any(r.t_new == 0 for r in plan.schedule.rounds)
        slotcode, rmeta = flat.packed()
        ids_k, vals_k = fused_cascade_pallas(
            V4, qb, jnp.asarray(slotcode), jnp.asarray(rmeta),
            jnp.asarray(cols), n_arms=plan.n, K=plan.K,
            t_final=flat.t_final, n_final=flat.n_final, interpret=True)
        ids_o, vals_o = ref.fused_cascade_ref(V4, qb, flat, cols,
                                              n_arms=plan.n, K=plan.K)
        np.testing.assert_array_equal(np.asarray(ids_k), ids_o)


def test_ops_wrappers_dispatch_interpret_on_cpu():
    assert not ops.on_tpu()
    V4 = jnp.asarray(_v4(2, 2, 8, 128, np.float32))
    out = ops.gather_block_dot(V4, jnp.zeros((1,), jnp.int32),
                               jnp.zeros((1,), jnp.int32),
                               jnp.ones((1, 128)))
    assert out.shape == (1, 8)
