"""Unit tests for the PR-9 observability layer (`repro.obs`).

Covers the three modules on their own contracts — metrics registry
semantics, Prometheus/JSON export (against a golden file), the
`summarize_latencies` percentile helper, span-tracer schema + reservoir
bounds, flight-recorder ring + dump-on-failure — and the integration
seams: metrics-vs-stats consistency on a live engine, the
`null_registry()` hard-off switch not perturbing served results, and the
FaultInjector per-kind seen/rates satellite.

Regenerate the Prometheus golden (only when the rendering intentionally
changes) with::

    PYTHONPATH=src python tests/test_obs.py --write
"""

import json
import os

import numpy as np
import pytest

from repro.launch.faults import FaultInjector
from repro.launch.serve import MIPSServeEngine, ServeRuntime
from repro.obs import (
    LATENCY_BUCKETS_MS,
    Counter,
    FlightRecorder,
    Gauge,
    Histogram,
    MetricsRegistry,
    SpanTracer,
    null_registry,
    summarize_latencies,
)
from repro.obs.trace import TID_REQ_BASE

GOLDEN_PROM = os.path.join(os.path.dirname(__file__), "data",
                           "golden_prometheus_pr9.prom")

DIM = 16


# ---- metrics: counter / gauge / histogram -------------------------------

def test_counter_basic():
    c = Counter("requests_total", "reqs", labels=("outcome",))
    c.inc(outcome="ok")
    c.inc(2.5, outcome="ok")
    c.inc(outcome="failed")
    assert c.get(outcome="ok") == 3.5
    assert c.get(outcome="failed") == 1.0
    assert c.get(outcome="never") == 0.0
    assert c.total() == 4.5


def test_counter_rejects_negative():
    c = Counter("x_total")
    with pytest.raises(ValueError, match="< 0"):
        c.inc(-1.0)


def test_counter_label_mismatch():
    c = Counter("x_total", labels=("a",))
    with pytest.raises(ValueError):
        c.inc()                       # missing label
    with pytest.raises(ValueError):
        c.inc(a="1", b="2")           # extra label
    with pytest.raises(ValueError):
        c.inc(b="2")                  # wrong label name


def test_counter_seed_pins_row_order():
    c = Counter("x_total", labels=("k",))
    c.seed(k="first")
    c.seed(k="second")
    c.inc(k="second")
    c.seed(k="second")                # seeding a live row is a no-op
    assert [r[0]["k"] for r in c.rows()] == ["first", "second"]
    assert c.get(k="first") == 0.0
    assert c.get(k="second") == 1.0


def test_gauge_set_and_callback():
    g = Gauge("depth")
    g.set(3)
    assert g.get() == 3.0
    box = {"v": 7}
    g.set_fn(lambda: box["v"])
    assert g.get() == 7.0
    box["v"] = 9
    assert g.get() == 9.0             # callback sampled at read time


def test_histogram_bucket_semantics():
    h = Histogram("lat_ms", buckets=(1.0, 10.0, 100.0))
    for v in (0.5, 1.0, 5.0, 100.0, 1e6):
        h.observe(v)
    cell = h.get()
    # le is inclusive: 1.0 lands in the le=1 bucket, 100.0 in le=100,
    # 1e6 in the implicit +Inf bucket
    assert cell["counts"] == [2, 1, 1, 1]
    assert cell["count"] == 5
    assert cell["sum"] == pytest.approx(0.5 + 1.0 + 5.0 + 100.0 + 1e6)
    assert h.count() == 5


def test_histogram_rejects_bad_buckets():
    with pytest.raises(ValueError):
        Histogram("h", buckets=())
    with pytest.raises(ValueError):
        Histogram("h", buckets=(2.0, 1.0))
    with pytest.raises(ValueError):
        Histogram("h", buckets=(1.0, 1.0))
    with pytest.raises(ValueError):
        Histogram("h", buckets=(1.0, float("inf")))


def test_invalid_names_rejected():
    with pytest.raises(ValueError):
        Counter("bad name")
    with pytest.raises(ValueError):
        Counter("ok_total", labels=("bad-label",))


# ---- metrics: registry --------------------------------------------------

def test_registry_get_or_create_shares():
    reg = MetricsRegistry()
    a = reg.counter("x_total", "help", ("k",))
    b = reg.counter("x_total", "ignored on reuse", ("k",))
    assert a is b
    a.inc(k="1")
    assert b.get(k="1") == 1.0


def test_registry_reregistration_mismatch_raises():
    reg = MetricsRegistry()
    reg.counter("x_total", labels=("k",))
    with pytest.raises(ValueError, match="re-registered"):
        reg.gauge("x_total", labels=("k",))           # kind mismatch
    with pytest.raises(ValueError, match="re-registered"):
        reg.counter("x_total", labels=("other",))     # label mismatch
    reg.histogram("h_ms", buckets=(1.0, 2.0))
    with pytest.raises(ValueError, match="buckets"):
        reg.histogram("h_ms", buckets=(1.0, 3.0))


def test_registry_adopt_by_reference():
    inner, outer = MetricsRegistry(), MetricsRegistry()
    c = inner.counter("inner_total")
    outer.adopt(inner)
    c.inc()
    assert outer.get("inner_total").total() == 1.0    # shared object
    outer.adopt(inner)                                # twice: no-op
    outer.adopt(outer)                                # self: no-op
    rogue = MetricsRegistry()
    rogue.counter("inner_total")
    with pytest.raises(ValueError, match="distinct objects"):
        outer.adopt(rogue)


def test_snapshot_shape():
    reg = MetricsRegistry()
    reg.counter("c_total", "a counter", ("k",)).inc(k="v")
    reg.gauge("g").set(2.0)
    reg.histogram("h_ms", buckets=(1.0, 2.0)).observe(1.5)
    snap = reg.snapshot()
    assert [m["name"] for m in snap["metrics"]] == ["c_total", "g", "h_ms"]
    c, g, h = snap["metrics"]
    assert c["kind"] == "counter"
    assert c["values"] == [{"labels": {"k": "v"}, "value": 1.0}]
    assert g["values"][0]["value"] == 2.0
    assert h["buckets"] == [1.0, 2.0]
    assert h["values"][0]["counts"] == [0, 1, 0]
    json.dumps(snap)                                  # serializable


def _golden_registry() -> MetricsRegistry:
    """A small deterministic registry exercising every rendering path:
    unlabeled/labeled counters, escaping, callback gauges, histograms
    (cumulative buckets, +Inf tail, integer vs float formatting)."""
    reg = MetricsRegistry()
    c = reg.counter("serve_outcomes_total", "Terminal request outcomes.",
                    ("outcome",))
    for o in ("answered", "degraded", "shed"):
        c.seed(outcome=o)
    c.inc(outcome="answered")
    c.inc(2, outcome="degraded")
    reg.counter("serve_requests_total", "Requests submitted.").inc(3)
    esc = reg.counter("esc_total", "Label escaping.", ("v",))
    esc.inc(v='quote " slash \\ newline \n end')
    g = reg.gauge("queue_depth", "Live queue depth.")
    g.set_fn(lambda: 4)
    reg.gauge("frac", "A float gauge.").set(0.25)
    h = reg.histogram("serve_latency_ms", "Latency (ms).", ("outcome",),
                      buckets=(1.0, 10.0, 100.0))
    for v in (0.5, 5.0, 5.0, 250.0):
        h.observe(v, outcome="answered")
    h.observe(50.0, outcome="degraded")
    return reg


def test_prometheus_rendering_matches_golden():
    got = _golden_registry().render_prometheus()
    with open(GOLDEN_PROM) as f:
        want = f.read()
    assert got == want


def test_prometheus_cumulative_buckets():
    txt = _golden_registry().render_prometheus()
    rows = [ln for ln in txt.splitlines()
            if ln.startswith('serve_latency_ms_bucket{outcome="answered"')]
    counts = [int(ln.rsplit(" ", 1)[1]) for ln in rows]
    assert counts == [1, 3, 3, 4]          # cumulative, +Inf last == count
    assert 'le="+Inf"' in rows[-1]


def test_registry_write_formats(tmp_path):
    reg = _golden_registry()
    p_prom = str(tmp_path / "m.prom")
    p_json = str(tmp_path / "m.json")
    reg.write(p_prom)
    reg.write(p_json)
    with open(p_prom) as f:
        assert f.read() == reg.render_prometheus()
    with open(p_json) as f:
        assert json.load(f) == json.loads(json.dumps(reg.snapshot()))


def test_null_registry_is_inert():
    reg = null_registry()
    c = reg.counter("x_total", labels=("k",))
    c.inc(k="1")
    c.inc(-5)                      # even invalid calls are dropped
    assert c.get(k="1") == 0.0
    assert c.total() == 0.0
    h = reg.histogram("h_ms")
    h.observe(3.0)
    assert h.sum() == 0.0 and h.count() == 0
    g = reg.gauge("g")
    g.set_fn(lambda: 1 / 0)        # callback never invoked
    assert g.get() == 0.0
    assert reg.snapshot() == {"metrics": []}
    other = MetricsRegistry()
    other.counter("y_total").inc()
    reg.adopt(other)               # no-op, no raise
    assert reg.snapshot() == {"metrics": []}


# ---- summarize_latencies ------------------------------------------------

def test_summarize_latencies_percentile_semantics():
    # 1..100 ms in seconds; np.percentile linear interpolation is the
    # pinned contract: p50 = 50.5, p95 = 95.05, p99 = 99.01
    lat_s = [i * 1e-3 for i in range(1, 101)]
    out = summarize_latencies(lat_s)
    assert list(out) == ["mean", "p50", "p95", "p99", "max"]
    assert out["mean"] == pytest.approx(50.5)
    assert out["p50"] == pytest.approx(np.percentile(np.arange(1, 101), 50))
    assert out["p95"] == pytest.approx(95.05)
    assert out["p99"] == pytest.approx(99.01)
    assert out["max"] == pytest.approx(100.0)


def test_summarize_latencies_empty_and_subset():
    assert summarize_latencies([]) == {
        "mean": 0.0, "p50": 0.0, "p95": 0.0, "p99": 0.0, "max": 0.0}
    # the micro-batching engine's legacy 4-key surface, order preserved
    out = summarize_latencies([2e-3], keys=("mean", "p50", "p95", "max"))
    assert list(out) == ["mean", "p50", "p95", "max"]
    assert out["max"] == pytest.approx(2.0)
    with pytest.raises(ValueError, match="unknown"):
        summarize_latencies([1e-3], keys=("p42",))


# ---- span tracer --------------------------------------------------------

def test_tracer_event_schema_and_nesting():
    tr = SpanTracer(max_requests=8, seed=0)
    tr.request_begin(0, 1.0, priority_class="default")
    tr.instant(0, "admitted", 1.0, depth=1)
    tr.span(0, "queued", 1.0, 1.5, didx=0)
    tr.span(0, "serve", 1.5, 2.0, rung=1, didx=0)
    tr.request_end(0, 2.0, "answered")
    tr.global_span("dispatch 0", 1.5, 2.0, didx=0)
    out = tr.export()
    evs = out["traceEvents"]
    assert isinstance(evs, list) and evs
    for ev in evs:
        assert {"ph", "name", "pid", "tid"} <= set(ev)
        if ev["ph"] == "X":
            assert {"ts", "dur", "cat", "args"} <= set(ev)
            assert ev["dur"] >= 0.0
        elif ev["ph"] == "i":
            assert "ts" in ev and ev["s"] == "t"
    # every per-request event nests inside the enclosing request span
    req = [e for e in evs if e["ph"] == "X"
           and e["name"] == "request rid=0"][0]
    assert req["args"]["status"] == "answered"
    assert req["args"]["priority_class"] == "default"
    t0, t1 = req["ts"], req["ts"] + req["dur"]
    for ev in evs:
        if ev.get("tid") == TID_REQ_BASE and ev["ph"] in ("X", "i"):
            assert ev["ts"] >= t0
            assert ev["ts"] + ev.get("dur", 0.0) <= t1
    # timestamps are virtual-clock microseconds
    assert req["ts"] == pytest.approx(1.0 * 1e6)
    assert req["dur"] == pytest.approx(1.0 * 1e6)
    json.dumps(out)                                   # loadable JSON


def test_tracer_reservoir_bounds_memory():
    tr = SpanTracer(max_requests=4, seed=0)
    for rid in range(100):
        if tr.request_begin(rid, rid * 1e-3):
            tr.request_end(rid, rid * 1e-3 + 1e-4, "answered")
    assert tr.n_seen == 100
    assert len(tr._per_req) == 4
    assert tr.n_dropped == 96
    od = tr.export()["otherData"]
    assert od["n_requests_seen"] == 100
    assert od["n_requests_sampled"] == 4
    assert od["n_requests_dropped"] == 96
    # deterministic: same seed, same survivors
    tr2 = SpanTracer(max_requests=4, seed=0)
    for rid in range(100):
        if tr2.request_begin(rid, rid * 1e-3):
            tr2.request_end(rid, rid * 1e-3 + 1e-4, "answered")
    assert sorted(tr._per_req) == sorted(tr2._per_req)


def test_tracer_unterminated_requests_closed_at_export():
    tr = SpanTracer(max_requests=4, seed=0)
    tr.request_begin(3, 0.5, priority_class="batch")
    reqs = [e for e in tr.export()["traceEvents"]
            if e["ph"] == "X" and e["name"] == "request rid=3"]
    assert len(reqs) == 1
    assert reqs[0]["dur"] == 0.0
    assert reqs[0]["args"]["status"] == "unterminated"
    # export is non-destructive: still open, can be closed later
    tr.request_end(3, 0.7, "shed")
    reqs = [e for e in tr.export()["traceEvents"]
            if e["ph"] == "X" and e["name"] == "request rid=3"]
    assert reqs[0]["args"]["status"] == "shed"


def test_tracer_unsampled_rids_are_noops():
    tr = SpanTracer(max_requests=1, seed=0)
    tr.span(99, "queued", 0.0, 1.0)       # never began: dropped
    tr.instant(99, "retry", 0.5)
    tr.request_end(99, 1.0, "answered")
    assert [e for e in tr.export()["traceEvents"] if e["ph"] != "M"] == []


# ---- flight recorder ----------------------------------------------------

def test_flight_ring_wraparound():
    fr = FlightRecorder(capacity=4)
    for i in range(10):
        fr.record("tick", t=i * 1e-3, i=i)
    evs = fr.events()
    assert len(evs) == 4
    assert [e["seq"] for e in evs] == [7, 8, 9, 10]   # oldest evicted
    assert [e["i"] for e in evs] == [6, 7, 8, 9]
    assert fr.n_recorded == 10


def test_flight_dump_payload(tmp_path):
    p = str(tmp_path / "flight.json")
    fr = FlightRecorder(capacity=8, path=p)
    assert fr.dump("nothing_recorded") == p           # empty ring is fine
    fr.record("admitted", t=0.1, rid=1)
    fr.record("quarantine_add", t=0.2, rid=1)
    assert fr.dump("request_failed", t=0.25) == p
    with open(p) as f:
        payload = json.load(f)
    assert payload["reason"] == "request_failed"
    assert payload["t"] == pytest.approx(0.25)
    assert payload["capacity"] == 8
    assert payload["n_recorded"] == 2
    assert payload["n_dumps"] == 2
    assert [e["kind"] for e in payload["events"]] == [
        "admitted", "quarantine_add"]
    assert fr.n_dumps == 2


def test_flight_dump_without_path_is_noop(tmp_path):
    fr = FlightRecorder(capacity=2)
    fr.record("x")
    assert fr.dump("whatever") is None
    explicit = str(tmp_path / "explicit.json")
    assert fr.dump("whatever", path=explicit) == explicit
    assert os.path.exists(explicit)


# ---- integration: engine / runtime seams --------------------------------

def _mini_runtime(metrics=None, tracer=None, flight=None, injector=None):
    rng = np.random.default_rng(3)
    table = rng.normal(size=(64, DIM)).astype(np.float32)
    return ServeRuntime(table, K=2, eps=0.3, delta=0.2, eps_floor=1.2,
                        degrade_rungs=2, lanes=2, batch_wait_ms=0.1,
                        queue_capacity=8, max_retries=1,
                        retry_backoff_ms=0.1, fault_injector=injector,
                        cache_entries=4, recall_sample_rate=0.0, seed=0,
                        metrics=metrics, tracer=tracer, flight=flight)


def _drive(rt, n=12, seed=4):
    rng = np.random.default_rng(seed)
    qs = rng.normal(size=(n, DIM)).astype(np.float32)
    t = 0.0
    rids = []
    for i in range(n):
        rids.append(rt.submit(qs[i], now=t))
        rt.poll(now=t + 1e-3)
        t += 2e-3
    rt.drain(now=t)
    return [rt.result(r) for r in rids]


def test_metrics_agree_with_stats():
    rt = _mini_runtime()
    _drive(rt)
    s = rt.stats()
    reg = rt.metrics
    assert reg.get("serve_requests_total").total() == s["requests"]
    assert reg.get("serve_outcomes_total").get(outcome="ok") == \
        s["outcomes"]["ok"]
    assert reg.get("serve_dispatches_total").total() == s["dispatches"]
    lat = reg.get("serve_latency_ms")
    assert lat.count() == s["outcomes"]["ok"] + s["outcomes"]["degraded"]
    assert reg.get("cascade_dispatches_total").total() >= s["dispatches"]


def test_null_registry_does_not_perturb_results():
    on = _drive(_mini_runtime())
    off = _drive(_mini_runtime(metrics=null_registry()))
    assert len(on) == len(off)
    for a, b in zip(on, off):
        assert a is not None and b is not None
        assert a.status == b.status
        if a.ids is not None or b.ids is not None:
            assert np.array_equal(np.asarray(a.ids), np.asarray(b.ids))
    rt_off = _mini_runtime(metrics=null_registry())
    _drive(rt_off)
    s = rt_off.stats()
    # registry-backed counters read 0 in off mode; list-backed latency
    # stats stay live (the bench baseline's throughput/p99 are real)
    assert s["requests"] == 0
    assert s["latency_ms"]["max"] > 0.0


def test_flight_dumps_on_failure_under_faults(tmp_path):
    p = str(tmp_path / "flight.json")
    inj = FaultInjector(7, error_rate=1.0, persistent_rate=1.0)
    fr = FlightRecorder(capacity=64, path=p)
    rt = _mini_runtime(flight=fr, injector=inj)
    res = _drive(rt, n=4)
    assert any(r.status == "failed" for r in res)
    assert os.path.exists(p)
    with open(p) as f:
        payload = json.load(f)
    assert payload["reason"] == "request_failed"
    kinds = {e["kind"] for e in payload["events"]}
    assert "fault_dispatch_error" in kinds
    assert "quarantine_add" in kinds
    assert fr.n_dumps >= 1


def test_tracer_wired_through_runtime():
    tr = SpanTracer(max_requests=64, seed=0)
    rt = _mini_runtime(tracer=tr)
    res = _drive(rt, n=8)
    out = tr.export()
    evs = out["traceEvents"]
    reqs = [e for e in evs if e["ph"] == "X"
            and e["name"].startswith("request rid=")]
    assert len(reqs) == 8                 # one enclosing span per request
    assert {e["args"]["status"] for e in reqs} == {r.status for r in res}
    names = {e["name"] for e in evs if e["ph"] == "X"}
    assert "queued" in names and "serve" in names
    assert any(n.startswith("dispatch ") for n in names)
    # dispatch spans carry the cascade annotations
    d = [e for e in evs if e["ph"] == "X"
         and e["name"].startswith("dispatch ")][0]
    for k in ("rung", "eps_served", "occupancy", "pull_frac"):
        assert k in d["args"]


def test_engine_metrics_surface():
    rng = np.random.default_rng(5)
    table = rng.normal(size=(32, DIM)).astype(np.float32)
    eng = MIPSServeEngine(table, K=2, eps=0.3, delta=0.2, batch_size=2,
                          deadline_ms=1.0, cache_entries=4,
                          recall_sample_rate=0.0, seed=0)
    qs = rng.normal(size=(5, DIM)).astype(np.float32)
    qs[4] = qs[0]
    for i in range(5):
        eng.submit(qs[i], now=i * 1e-3)
        eng.poll(now=i * 1e-3)
    eng.drain(now=1.0)
    reg = eng.metrics
    assert reg.get("serve_requests_total").total() == 5
    assert reg.get("serve_cache_hits_total").total() == eng.n_cache_hits
    b = reg.get("serve_batches_total")
    assert b.get(trigger="full") + b.get(trigger="deadline") == \
        eng.n_batches
    assert reg.get("serve_batch_occupancy").count() == eng.n_batches
    assert reg.get("serve_latency_ms").count() == 5


# ---- fault injector seen/rates satellite --------------------------------

def test_fault_injector_rates():
    inj = FaultInjector(3, latency_rate=0.5, latency_ms=2.0,
                        error_rate=0.25, flush_failure_rate=1.0)
    n_lat = sum(inj.latency_s(i) > 0 for i in range(40))
    # dispatch_error(i, 0) is non-None iff dispatch i has >= 1 injected
    # failing attempt — exactly the rate numerator's definition
    n_err = sum(inj.dispatch_error(i, 0) is not None for i in range(40))
    n_flush = 0
    for _ in range(10):
        try:
            inj._flush_hook()
        except Exception:
            n_flush += 1
    s = inj.stats()
    assert s["seen"] == {"latency": 40, "error": 40, "flush": 10}
    assert s["latency_spikes"] == n_lat
    assert s["rates"]["latency"] == pytest.approx(n_lat / 40)
    assert s["rates"]["error"] == pytest.approx(n_err / 40)
    assert s["rates"]["flush"] == pytest.approx(n_flush / 10)
    assert all(0.0 <= v <= 1.0 for v in s["rates"].values())
    # injected_latency_ms is in the same unit as the latency histograms
    assert s["injected_latency_ms"] == pytest.approx(
        inj.metrics.get("faults_injected_latency_ms").sum())


def test_fault_injector_zero_rate_counts_seen():
    inj = FaultInjector(0)                 # all rates zero
    inj.latency_s(0)
    inj.dispatch_error(0, 0)
    s = inj.stats()
    assert s["seen"]["latency"] == 1
    assert s["seen"]["error"] == 1
    assert s["rates"] == {"latency": 0.0, "error": 0.0, "flush": 0.0}


if __name__ == "__main__":
    import sys
    if "--write" in sys.argv:
        with open(GOLDEN_PROM, "w") as f:
            f.write(_golden_registry().render_prometheus())
        print(f"wrote {GOLDEN_PROM}")
    else:
        print(_golden_registry().render_prometheus())
