"""Static elimination schedule invariants (Algorithm 1, lines 4-11)."""

import pytest

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.core.schedule import make_schedule


@given(st.integers(2, 5000), st.integers(2, 100_000), st.integers(1, 16),
       st.floats(0.01, 0.9), st.floats(0.01, 0.4))
@settings(max_examples=200, deadline=None)
def test_schedule_invariants(n, N, K, eps, delta):
    K = min(K, n - 1)
    s = make_schedule(n, N, K=K, eps=eps, delta=delta)
    assert s.rounds, "n > K must yield at least one round"
    # survivor counts strictly decrease to K
    sizes = [r.n_arms for r in s.rounds] + [s.rounds[-1].n_keep]
    assert all(a > b for a, b in zip(sizes, sizes[1:])) or len(sizes) == 2
    assert s.rounds[-1].n_keep == K
    # cumulative pulls nondecreasing, bounded by N (Corollary 2)
    ts = [r.t_cum for r in s.rounds]
    assert all(a <= b for a, b in zip(ts, ts[1:]))
    assert ts[-1] <= N
    # never slower than exhaustive search
    assert s.total_pulls <= s.naive_pulls
    # halving: each round keeps K + floor((n_l - K)/2)
    for r in s.rounds:
        assert r.n_keep == r.K if False else r.n_keep == s.K + (r.n_arms - s.K) // 2


def test_k_geq_n_short_circuits():
    s = make_schedule(5, 100, K=5)
    assert not s.rounds and s.total_pulls == 0


def test_round_count_logarithmic():
    s = make_schedule(2 ** 16, 10 ** 5, K=1, eps=0.2, delta=0.1)
    assert len(s.rounds) <= 17


def test_eps_delta_budgets():
    # sum eps_l <= eps, sum delta_l <= delta (Theorem 1's telescoping)
    s = make_schedule(1000, 10 ** 5, K=1, eps=0.3, delta=0.2)
    assert sum(r.eps_l for r in s.rounds) <= 0.3 + 1e-9
    assert sum(r.delta_l for r in s.rounds) <= 0.2 + 1e-9


def test_speedup_grows_with_eps():
    sp = [make_schedule(10_000, 10 ** 5, eps=e, delta=0.1).speedup
          for e in (0.05, 0.1, 0.3, 0.6)]
    assert all(a <= b + 1e-9 for a, b in zip(sp, sp[1:]))
