"""End-to-end behaviour: training convergence, fault-tolerant resume,
serving with the paper's MIPS decode, and a small sharded run."""

import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import restore_checkpoint, save_checkpoint
from repro.configs import REGISTRY
from repro.data.synthetic import LMStream
from repro.models.model import init_params
from repro.models.steps import decode_step, prefill_step, train_step
from repro.optim.adamw import AdamWConfig, init_opt


@pytest.fixture(scope="module")
def trained():
    cfg = REGISTRY["tinyllama-1.1b"].smoke()
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=100)
    opt = init_opt(params)
    stream = LMStream(cfg.vocab, batch=4, seq=32, seed=0)
    fn = jax.jit(lambda p, o, b: train_step(p, o, b, cfg, opt_cfg))
    losses = []
    for i in range(30):
        b = {k: jnp.asarray(v) for k, v in stream.batch_at(i).items()}
        params, opt, m = fn(params, opt, b)
        losses.append(float(m["loss"]))
    return cfg, params, opt, opt_cfg, stream, losses


def test_training_reduces_loss(trained):
    *_, losses = trained
    assert losses[-1] < losses[0] - 0.3


def test_checkpoint_resume_bit_exact(trained, tmp_path):
    """Kill-and-restart at step 30 must match uninterrupted steps 30..35."""
    cfg, params, opt, opt_cfg, stream, _ = trained
    fn = jax.jit(lambda p, o, b: train_step(p, o, b, cfg, opt_cfg))
    save_checkpoint(str(tmp_path), 30, {"params": params, "opt": opt})

    pA, oA = params, opt
    for i in range(30, 35):
        b = {k: jnp.asarray(v) for k, v in stream.batch_at(i).items()}
        pA, oA, _ = fn(pA, oA, b)

    restored, step = restore_checkpoint(str(tmp_path),
                                        {"params": params, "opt": opt})
    pB, oB = restored["params"], restored["opt"]
    for i in range(step, 35):  # indexable stream -> no data skew on resume
        b = {k: jnp.asarray(v) for k, v in stream.batch_at(i).items()}
        pB, oB, _ = fn(pB, oB, b)

    for a, b_ in zip(jax.tree.leaves(pA), jax.tree.leaves(pB)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b_, np.float32))


def test_serving_boundedme_matches_exact_over_rollout(trained):
    cfg, params, *_ = trained
    cfg_e = dataclasses.replace(cfg, mips_mode="exact")
    cfg_b = dataclasses.replace(cfg, mips_mode="boundedme", mips_eps=0.05)
    prompt = jnp.asarray(
        np.random.default_rng(5).integers(0, cfg.vocab, (2, 8)), jnp.int32)
    _, cache_e = prefill_step(params, cfg_e, prompt, cache_len=32)
    _, cache_b = prefill_step(params, cfg_b, prompt, cache_len=32)
    toks_e, toks_b = [], []
    te = tb = prompt[:, -1:]
    for step in range(6):
        pos = jnp.int32(8 + step)
        ne, cache_e = decode_step(params, cfg_e, cache_e, te, pos)
        nb, cache_b = decode_step(params, cfg_b, cache_b, tb, pos,
                                  key=jax.random.PRNGKey(step))
        toks_e.append(np.asarray(ne))
        toks_b.append(np.asarray(nb))
        te, tb = ne[:, None], nb[:, None]
    agree = np.mean([np.array_equal(a, b) for a, b in zip(toks_e, toks_b)])
    assert agree >= 5 / 6  # eps=0.05, delta=0.1: near-always identical


@pytest.mark.slow
def test_sharded_train_step_8_devices():
    """Mini dry-run with real execution on 8 fake CPU devices."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import REGISTRY
from repro.distributed.sharding import logical_mesh
from repro.distributed.specs import param_pspecs, batch_pspecs
from repro.models.model import init_params
from repro.models.steps import train_step
from repro.optim.adamw import AdamWConfig, init_opt
import dataclasses
cfg = dataclasses.replace(REGISTRY["qwen3-moe-30b-a3b"].smoke(), vocab_pad=64)
mesh = jax.make_mesh((2, 4), ("data", "model"))
params = init_params(cfg, jax.random.PRNGKey(0))
opt = init_opt(params)
with logical_mesh(mesh):
    pspecs = param_pspecs(cfg, params, mesh)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    params = jax.device_put(params, psh)
    b = {"tokens": jnp.zeros((4, 32), jnp.int32),
         "labels": jnp.zeros((4, 32), jnp.int32)}
    fn = jax.jit(lambda p, o, bb: train_step(p, o, bb, cfg, AdamWConfig()))
    p2, o2, m = fn(params, opt, b)
    assert np.isfinite(float(m["loss"])), m
print("SHARDED_OK", float(m["loss"]))
"""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=480)
    assert "SHARDED_OK" in r.stdout, r.stdout + r.stderr
