"""MIPS baselines: correctness limits + cost accounting sanity."""

import numpy as np
import pytest

from repro.baselines import (build_greedy, build_lsh, build_pca_tree,
                             exact_mips, greedy_mips, lsh_mips, pca_mips)


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(0)
    V = rng.normal(size=(1500, 96)).astype(np.float64)
    q = rng.normal(size=96)
    return V, q


def test_exact_is_argmax(data):
    V, q = data
    r = exact_mips(V, q, K=3)
    assert r.topk[0] == np.argmax(V @ q)
    assert r.query_multiplies == V.size


def test_greedy_full_budget_is_exact(data):
    V, q = data
    idx = build_greedy(V)
    r = greedy_mips(idx, q, K=5, budget=V.shape[0])
    assert set(r.topk.tolist()) == set(exact_mips(V, q, 5).topk.tolist())


def test_greedy_budget_tradeoff(data):
    V, q = data
    idx = build_greedy(V)
    truth = set(exact_mips(V, q, 5).topk.tolist())
    prec = []
    for budget in (10, 100, 1000):
        r = greedy_mips(idx, q, K=5, budget=budget)
        prec.append(len(set(r.topk.tolist()) & truth) / 5)
    assert prec[-1] >= prec[0]
    assert prec[-1] >= 0.8  # large budget ~ exact


def test_lsh_high_params_high_recall(data):
    V, q = data
    idx = build_lsh(V, a=4, b=48, seed=1)
    truth = exact_mips(V, q, 1).topk[0]
    r = lsh_mips(idx, q, K=1)
    # OR-amplified 48 tables at 4 bits: the argmax bucket almost surely hits
    assert truth in r.topk or r.candidates > 0
    assert r.preprocess_multiplies == V.shape[0] * (V.shape[1] + 1) * 4 * 48


def test_pca_spill_recovers_truth(data):
    V, q = data
    tree = build_pca_tree(V, depth=4)
    truth = exact_mips(V, q, 1).topk[0]
    r = pca_mips(tree, q, K=1, spill=1e9)  # full spill == exhaustive
    assert r.topk[0] == truth
    r0 = pca_mips(tree, q, K=1, spill=0.0)
    assert r0.candidates <= r.candidates


def test_costs_monotone_in_candidates(data):
    V, q = data
    tree = build_pca_tree(V, depth=6)
    r_narrow = pca_mips(tree, q, K=1, spill=0.0)
    r_wide = pca_mips(tree, q, K=1, spill=0.5)
    assert r_narrow.query_multiplies <= r_wide.query_multiplies
