"""BoundedSE (beyond-paper anytime variant): guarantee + adaptivity."""

import numpy as np
import pytest

from repro.core import bounded_me
from repro.core.bounded_se import bounded_se
from repro.data.synthetic import adversarial_dataset


def _easy_instance(n, N, gap=0.3, seed=0):
    """One clearly-best arm: large-gap (easy) MAB-BP instance."""
    rng = np.random.default_rng(seed)
    means = np.full(n, 0.3)
    means[0] = 0.3 + gap
    R = (rng.uniform(0, 1, (n, N)) < means[:, None]).astype(np.float32)
    # random oracle order is fine here (not adversarial)
    return R, means


def test_guarantee_value_adversarial_uniform_order():
    """Adversarial VALUES, uniform pull order (the MIPS model: the
    algorithm draws coordinates in its own random order).  The anytime
    radius requires this; order-adversaries need BoundedME (docstring)."""
    eps, delta = 0.2, 0.2
    rng = np.random.default_rng(99)
    fails = 0
    trials = 20
    for s in range(trials):
        R = adversarial_dataset(300, 3000, seed=s)
        R = rng.permuted(R, axis=1)          # algorithm-controlled order
        means = R.mean(axis=1)
        res = bounded_se(R, K=1, eps=eps, delta=delta)
        if means.max() - means[res.topk[0]] >= eps:
            fails += 1
    assert fails / trials <= delta + 0.12


def test_order_adversary_documented_failure_mode():
    """Under the paper's order-adversary the anytime variant may return a
    tied-looking arm early — this is the documented reason BoundedME (not
    BoundedSE) is the order-robust default.  We only assert it never
    exceeds the exhaustive budget there."""
    R = adversarial_dataset(300, 3000, seed=0)
    res = bounded_se(R, K=1, eps=0.2, delta=0.2)
    assert res.total_pulls <= R.size


def test_adaptively_beats_boundedme_on_easy_instances():
    R, means = _easy_instance(500, 5000, gap=0.35)
    se = bounded_se(R, K=1, eps=0.05, delta=0.1)
    me = bounded_me(R, K=1, eps=0.05, delta=0.1)
    assert se.topk[0] == 0 and me.topk[0] == 0
    # the anytime radius stops early once the gap is resolved
    assert se.total_pulls < me.total_pulls


def test_never_exceeds_exhaustive():
    R = adversarial_dataset(200, 1000, seed=3)
    res = bounded_se(R, K=1, eps=1e-6, delta=0.05)
    assert res.total_pulls <= R.size
    # eps -> 0: must identify the exact best arm (radius hits 0 at m=N)
    assert res.topk[0] == np.argmax(R.mean(axis=1))


def test_topk():
    R, means = _easy_instance(300, 4000, gap=0.25, seed=7)
    means[1] = 0.5
    res = bounded_se(R, K=2, eps=0.3, delta=0.1)
    assert 0 in res.topk.tolist()
