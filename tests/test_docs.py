"""Doc coverage is part of tier-1: the public API must stay documented.

Delegates to tools/check_docstrings.py (docstring coverage, pure AST) and
tools/check_links.py (markdown link + path-reference liveness), so the CI
docs job and the test suite can never disagree about what "covered" means.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "tools"))

import check_docstrings  # noqa: E402
import check_links  # noqa: E402


def test_public_api_docstrings_covered():
    problems = check_docstrings.check()
    assert not problems, "\n".join(problems)


def test_contracted_symbols_exist():
    """Every contract entry must point at a live symbol (no rot)."""
    for rel, contracts in check_docstrings.API_CONTRACTS.items():
        assert rel in check_docstrings.AUDITED_MODULES, rel
        assert contracts, rel


def test_doc_links_live():
    """README/DESIGN/docs references must point at files that exist."""
    problems = check_links.check()
    assert not problems, "\n".join(problems)


def test_link_checker_detects_breakage(tmp_path, monkeypatch):
    """The checker itself must not be vacuous: a planted broken link and a
    dangling backtick path must both be reported.  The fixture doc lives
    in tmp_path (absolute entries resolve as-is against REPO), keeping
    the repo working tree untouched."""
    bad = tmp_path / "broken.md"
    bad.write_text("[x](no/such/file.md) and `src/repro/core/missing.py`")
    monkeypatch.setattr(check_links, "AUDITED_DOCS", [str(bad)])
    problems = check_links.check()
    assert any("broken link" in p for p in problems), problems
    assert any("dangling path" in p for p in problems), problems
