"""Doc coverage is part of tier-1: the public API must stay documented.

Delegates to tools/check_docstrings.py (pure AST — no jax import), so the
CI step and the test suite can never disagree about what "covered" means.
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "tools"))

import check_docstrings  # noqa: E402


def test_public_api_docstrings_covered():
    problems = check_docstrings.check()
    assert not problems, "\n".join(problems)


def test_contracted_symbols_exist():
    """Every contract entry must point at a live symbol (no rot)."""
    for rel, contracts in check_docstrings.API_CONTRACTS.items():
        assert rel in check_docstrings.AUDITED_MODULES, rel
        assert contracts, rel
