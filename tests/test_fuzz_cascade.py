"""Property-based differential fuzzer for the fused cascade (ISSUE 5+7+8).

Three independent implementations of the same flat-schedule program —
the Pallas kernel (interpret mode), the `lax.scan`/dense jnp fallback,
and the deliberately naive numpy oracle (`repro.kernels.ref`) — must
agree across randomized geometry: ragged n and N, K > tile, caller
padding via ``n_valid``, the full fp32/int8/int4/pq precision ladder
(ISSUE 8 — the oracle unpacks nibbles and walks pq LUTs with its own
independent numpy arithmetic), hoeffding/bernstein bound families,
adaptive on/off, widened ``k_out``, and (ISSUE 7) the pull mode —
'row', 'coord' (narrow coordinate tiles, including d not a multiple of
the feature-tile width) and 'hybrid' (whichever concrete mode the
dispatcher resolves must itself pass the trio check).

Agreement contract (the same one the PR-1/PR-3 suites pin):

  * kernel vs jnp fallback — **bitwise** on ids, scores and (adaptive)
    per-query ``rounds_used``;
  * kernel vs numpy oracle — ids and ``rounds_used`` exact, scores to
    tight float tolerance (numpy's BLAS matvec reduction order is not
    XLA's, so the accumulators differ in the last bits).

A fixed parametrized grid runs from a clean checkout (no hypothesis
needed); the hypothesis fuzzer on top randomizes the same space and is
skipped gracefully when hypothesis is absent (`optional_hypothesis`).
All comparisons use ``final_exact=False`` — the one configuration where
kernel and fallback are specified to be bitwise-identical (the
final-exact paths diverge by design: coverage completion vs dense
rescore).
"""

import jax
import numpy as np
import pytest

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.core.boundedme_jax import (_pad_operands, _quantize_table,
                                      _tile_major, bounded_me_decode,
                                      make_plan)
from repro.core.quantize import quantize_blocks
from repro.core.schedule import cert_coeffs, flatten_schedule
from repro.kernels.ref import fused_cascade_ref


def _oracle_decode(V, Q, key, plan, *, k_out, n_valid, adaptive):
    """Numpy-oracle mirror of `bounded_me_decode(final_exact=False)`."""
    import jax.numpy as jnp

    C = plan.block
    B = Q.shape[0]
    Vp, Qp = _pad_operands(jnp.asarray(V), jnp.asarray(Q), plan)
    V4 = _tile_major(Vp, plan)
    Qb = np.asarray(Qp).reshape(B, plan.n_blocks, C)
    perm = np.asarray(jax.random.permutation(key, plan.n_blocks))
    flat = flatten_schedule(plan.schedule, final_coverage=False)
    cols = perm[flat.bpos]
    scale = np.float32((plan.n_blocks * C) / plan.N)
    cert = cert_coeffs(plan.schedule) if adaptive else None
    vscale = qscale = codebook = None
    packed_int4 = False
    if plan.precision in ("int8", "int4"):
        Vq, vscale = _quantize_table(V4, plan)
        Q8, qscale = quantize_blocks(jnp.asarray(Qb))
        V4, Qb = np.asarray(Vq), np.asarray(Q8)
        vscale, qscale = np.asarray(vscale), np.asarray(qscale)
        packed_int4 = plan.precision == "int4"
    elif plan.precision == "pq":
        # same deterministic trainer/encoder the kernel path uses — the
        # oracle sees the identical codes + codebook, queries stay fp32
        codes, cb = _quantize_table(V4, plan)
        V4, codebook = np.asarray(codes), np.asarray(cb)
    else:
        V4 = np.asarray(V4)
    ids, vals, rounds = [], [], []
    for b in range(B):
        out = fused_cascade_ref(
            V4, Qb[b], flat, cols, n_arms=plan.n, K=k_out,
            vscale=vscale, qscale=None if qscale is None else qscale[b],
            codebook=codebook, packed_int4=packed_int4,
            n_valid=n_valid, cert=cert, k_cert=plan.K)
        ids.append(out[0])
        vals.append(out[1] * scale)
        if adaptive:
            rounds.append(out[2])
    out = (np.stack(ids), np.stack(vals))
    return (*out, np.asarray(rounds, np.int32)) if adaptive else out


def _check_trio(n, N, K, tile, block, n_valid, precision, bound, adaptive,
                B, eps, widen_k_out, seed, pull_mode="row", coord_block=128):
    rng = np.random.default_rng(seed)
    V = rng.normal(size=(n, N)).astype(np.float32)
    Q = rng.normal(size=(B, N)).astype(np.float32)
    # pq refuses to guess a worst-case bound (DESIGN.md §10); the trio
    # contract only needs the *same* schedule on all three paths, so any
    # fixed value works — honesty of the bound is the guarantee suite's job
    quant_err = 0.05 if precision == "pq" else None
    plan = make_plan(n, N, K=K, eps=eps, delta=0.1, value_range=8.0,
                     tile=tile, block=block, precision=precision,
                     bound=bound, pull_mode=pull_mode,
                     coord_block=coord_block, quant_err=quant_err)
    assert plan.pull_mode in ("row", "coord")   # hybrid resolves concrete
    k_out = min(plan.K + 2, plan.k_out_cap) if widen_k_out else plan.K
    key = jax.random.PRNGKey(seed)
    kw = dict(plan=plan, final_exact=False, k_out=k_out, n_valid=n_valid,
              adaptive=adaptive)
    out_k = bounded_me_decode(V, Q, key, use_pallas=True, **kw)
    out_j = bounded_me_decode(V, Q, key, use_pallas=False, **kw)
    out_o = _oracle_decode(V, Q, key, plan, k_out=k_out, n_valid=n_valid,
                           adaptive=adaptive)
    tag = (n, N, K, tile, block, n_valid, precision, bound, adaptive, B)
    # kernel vs fallback: bitwise
    np.testing.assert_array_equal(np.asarray(out_k[0]), np.asarray(out_j[0]),
                                  err_msg=f"ids vs fallback {tag}")
    np.testing.assert_array_equal(np.asarray(out_k[1]), np.asarray(out_j[1]),
                                  err_msg=f"scores vs fallback {tag}")
    # kernel vs oracle: ids exact, scores to tight tolerance
    np.testing.assert_array_equal(np.asarray(out_k[0]), np.asarray(out_o[0]),
                                  err_msg=f"ids vs oracle {tag}")
    np.testing.assert_allclose(np.asarray(out_k[1]), np.asarray(out_o[1]),
                               rtol=2e-5, atol=1e-7,
                               err_msg=f"scores vs oracle {tag}")
    if adaptive:
        np.testing.assert_array_equal(np.asarray(out_k[2]),
                                      np.asarray(out_j[2]),
                                      err_msg=f"rounds vs fallback {tag}")
        np.testing.assert_array_equal(np.asarray(out_k[2]),
                                      np.asarray(out_o[2]),
                                      err_msg=f"rounds vs oracle {tag}")


# deterministic grid: runs from a clean checkout, covers every axis once
GRID = [
    # n,   N,    K, tile, blk, n_valid, precision, bound,      adapt, B
    (96,   512,  2, 8,    64,  96,      "fp32",    "hoeffding", False, 2),
    (96,   512,  2, 8,    64,  96,      "fp32",    "hoeffding", True,  2),
    (100,  700,  3, 8,    128, 87,      "fp32",    "bernstein", True,  1),
    (64,   384,  12, 4,   64,  64,      "fp32",    "hoeffding", True,  2),
    (96,   512,  2, 8,    64,  96,      "int8",    "hoeffding", True,  2),
    (77,   300,  4, 8,    32,  60,      "int8",    "bernstein", True,  3),
    (33,   257,  1, 8,    64,  33,      "fp32",    "bernstein", True,  1),
    (96,   512,  5, 8,    64,  3,       "fp32",    "hoeffding", True,  1),
    # ISSUE 8: sub-byte tiers through the identical trio contract —
    # nibble-packed int4 and LUT-walking pq, incl. ragged d (700, 257
    # are not multiples of the block, exercising the zero-padded tail)
    (96,   512,  2, 8,    64,  96,      "int4",    "hoeffding", True,  2),
    (100,  700,  3, 8,    128, 87,      "int4",    "bernstein", True,  1),
    (96,   512,  2, 8,    64,  96,      "pq",      "hoeffding", True,  2),
    (33,   257,  1, 8,    64,  33,      "pq",      "bernstein", True,  1),
]


@pytest.mark.parametrize(
    "n,N,K,tile,block,n_valid,precision,bound,adaptive,B", GRID)
def test_grid_kernel_fallback_oracle_bitwise(n, N, K, tile, block, n_valid,
                                             precision, bound, adaptive, B):
    _check_trio(n, N, K, tile, block, n_valid, precision, bound, adaptive,
                B, eps=0.7, widen_k_out=(K < n), seed=n + 7 * K)


# coordinate / hybrid pull modes (ISSUE 7) — same trio contract, narrow
# feature tiles; includes d NOT a multiple of the coord tile (700 % 128,
# 300 % 96, 257 % 64 != 0, exercising the zero-padded ragged last tile)
COORD_GRID = [
    # n,  N,   K, tile, cb,  n_valid, precision, bound,      adapt, B, mode
    (96,  512, 2, 8,    128, 96,  "fp32", "hoeffding", False, 2, "coord"),
    (100, 700, 3, 8,    128, 87,  "fp32", "bernstein", True,  1, "coord"),
    (96,  512, 2, 8,    128, 96,  "int8", "hoeffding", True,  2, "coord"),
    (77,  300, 4, 8,    96,  60,  "int8", "bernstein", True,  3, "coord"),
    (33,  257, 1, 8,    64,  33,  "fp32", "hoeffding", True,  1, "coord"),
    (96,  512, 2, 8,    128, 96,  "fp32", "hoeffding", False, 2, "hybrid"),
    (100, 700, 3, 8,    128, 87,  "int8", "hoeffding", True,  2, "hybrid"),
    # ISSUE 8: int4/pq under narrow coordinate tiles (coord_block is the
    # effective pull width — 96 % pq_subdims == 0, 64 even for nibbles)
    (96,  512, 2, 8,    128, 96,  "int4", "hoeffding", True,  2, "coord"),
    (77,  300, 4, 8,    96,  60,  "pq",   "bernstein", True,  3, "coord"),
    (100, 700, 3, 8,    128, 87,  "int4", "hoeffding", True,  2, "hybrid"),
    (96,  512, 2, 8,    64,  96,  "pq",   "hoeffding", True,  1, "hybrid"),
]


@pytest.mark.parametrize(
    "n,N,K,tile,cb,n_valid,precision,bound,adaptive,B,mode", COORD_GRID)
def test_coord_grid_kernel_fallback_oracle_bitwise(
        n, N, K, tile, cb, n_valid, precision, bound, adaptive, B, mode):
    # row block stays at 128 — the width envelope the bitwise contract has
    # always been pinned at (a hybrid resolving to 'row' then lands on the
    # same geometry the row GRID already certifies)
    _check_trio(n, N, K, tile, 128, n_valid, precision, bound, adaptive,
                B, eps=0.7, widen_k_out=(K < n), seed=n + 7 * K,
                pull_mode=mode, coord_block=cb)


def test_fewer_live_rows_than_k_out_no_duplicates():
    """Regression for a pre-existing kernel bug this fuzzer surfaced: with
    fewer live rows than ``keep``/``k_out`` the in-kernel extraction's
    ``-inf`` markers tied with the exhausted maximum and re-extracted the
    same slot — duplicating winners (which carry ids < n_valid and so
    would survive the sharded merge's filler mask) and silently dropping
    valid rows.  Extraction now uses NaN markers (lax.top_k's
    distinct-index semantics): every valid row appears exactly once and
    filler slots carry -inf scores."""
    rng = np.random.default_rng(0)
    V = rng.normal(size=(96, 512)).astype(np.float32)
    Q = rng.normal(size=(2, 512)).astype(np.float32)
    plan = make_plan(96, 512, K=5, eps=0.7, delta=0.1, value_range=8.0,
                     block=64)
    key = jax.random.PRNGKey(3)
    n_live = 3
    for adaptive in (False, True):
        kw = dict(plan=plan, final_exact=False, k_out=7, n_valid=n_live,
                  adaptive=adaptive)
        out_k = bounded_me_decode(V, Q, key, use_pallas=True, **kw)
        out_j = bounded_me_decode(V, Q, key, use_pallas=False, **kw)
        np.testing.assert_array_equal(np.asarray(out_k[0]),
                                      np.asarray(out_j[0]))
        np.testing.assert_array_equal(np.asarray(out_k[1]),
                                      np.asarray(out_j[1]))
        ids = np.asarray(out_k[0])
        scores = np.asarray(out_k[1])
        for b in range(2):
            live = ids[b][scores[b] > -np.inf]
            assert sorted(live.tolist()) == list(range(n_live)), adaptive
            assert np.all(scores[b][n_live:] == -np.inf), adaptive


@given(st.data())
@settings(max_examples=12, deadline=None, derandomize=True)
def test_fuzz_kernel_fallback_oracle_bitwise(data):
    n = data.draw(st.integers(10, 160), label="n")
    N = data.draw(st.integers(64, 1200), label="N")
    K = data.draw(st.integers(1, min(5, n)), label="K")
    tile = data.draw(st.sampled_from([4, 8]), label="tile")
    block = data.draw(st.sampled_from([32, 64, 128]), label="block")
    n_valid = data.draw(st.integers(1, n), label="n_valid")
    precision = data.draw(st.sampled_from(["fp32", "int8", "int4", "pq"]),
                          label="precision")
    bound = data.draw(st.sampled_from(["hoeffding", "bernstein"]),
                      label="bound")
    adaptive = data.draw(st.booleans(), label="adaptive")
    B = data.draw(st.integers(1, 2), label="B")
    eps = data.draw(st.sampled_from([0.4, 0.8, 1.6]), label="eps")
    widen = data.draw(st.booleans(), label="widen_k_out")
    seed = data.draw(st.integers(0, 2**16), label="seed")
    pull_mode = data.draw(st.sampled_from(["row", "coord", "hybrid"]),
                          label="pull_mode")
    coord_block = data.draw(st.sampled_from([32, 64, 96, 128]),
                            label="coord_block")
    _check_trio(n, N, K, tile, block, n_valid, precision, bound, adaptive,
                B, eps=eps, widen_k_out=widen, seed=seed,
                pull_mode=pull_mode, coord_block=coord_block)