"""Dynamic table store (ISSUE 4): liveness, bit-identity, zero recompiles.

The store contract under test (DESIGN.md §11):

* deleted ids are *never* returned — adversarially, on an all-negative
  table where a zeroed tombstone row would out-score every live arm;
* an engine after an arbitrary upsert/delete burst is equivalent to a
  freshly built engine on the store's snapshot — byte-equal buffers
  (incl. the quantized shadow: int8/int4 codes+scales, pq codes against
  the frozen codebook) and bit-identical decode output under the same
  key, across the full fp32/int8/int4/pq precision ladder (ISSUE 8);
* a mutation stream compiles **zero** new executables (the jit-cache
  assertion): live counts ride through the traced ``n_valid``, writes
  reuse one donated `dynamic_update_slice` executable.

The 2-device `ShardedTableStore` variants run in a subprocess with fake
CPU devices (same idiom as tests/test_sharded_serve.py); the CI 2-device
matrix step re-runs this file under an outer XLA flag.
"""

import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core.boundedme_jax import bounded_me_decode, make_plan
from repro.launch.serve import MIPSServeEngine
from repro.store import DynamicTableStore

_N, _DIM, _K = 192, 128, 3
_BLOCK = 64


def _table(seed=0, n=_N, scale=1.0):
    rng = np.random.default_rng(seed)
    return (scale * rng.normal(size=(n, _DIM))).astype(np.float32)


def _engine(store, **kw):
    kw.setdefault("K", _K)
    kw.setdefault("eps", 1e-4)
    kw.setdefault("delta", 0.05)
    kw.setdefault("value_range", 16.0)
    kw.setdefault("batch_size", 2)
    kw.setdefault("deadline_ms", 1.0)
    return MIPSServeEngine(store, **kw)


def _query(store, eng, q):
    rid = eng.submit(q, now=float(eng.n_requests))
    eng.drain(now=float(eng.n_requests))
    return eng.result(rid)


def _masked_truth(store, q, K=_K):
    s = store.host_table() @ q
    s[~store.live_mask()] = -np.inf
    slots = np.argsort(-s)[:K]
    return store.external_ids(slots), s[slots]


class TestStoreSemantics:
    def test_roundtrip_and_dense_prefix(self):
        V = _table()
        st = DynamicTableStore(V, block=_BLOCK, capacity_slack=1.5)
        assert st.capacity_rows % st.tile == 0
        assert st.capacity_rows >= int(np.ceil(_N * 1.5))
        assert st.n_live == _N and st.version == 0
        rng = np.random.default_rng(1)
        row = rng.normal(size=_DIM).astype(np.float32)
        new_id = st.append(row)
        st.upsert(7, 2 * row)
        st.delete(3)                       # interior: swap-filled from tail
        assert st.pending_updates == 3
        info = st.flush_updates()
        assert info["applied"] == 3 and st.version == 3
        assert st.pending_updates == 0
        # live slots are a dense prefix; vacated tail slot zeroed
        mask = st.live_mask()
        assert mask[:st.n_live].all() and not mask[st.n_live:].any()
        np.testing.assert_array_equal(st.host_table()[st.n_live:], 0.0)
        # host mirror == device buffer, byte for byte
        np.testing.assert_array_equal(st.host_table(),
                                      np.asarray(st.device_table()))
        # ids are stable through the swap
        np.testing.assert_array_equal(
            st.host_table()[st._id2slot[new_id]], row)
        np.testing.assert_array_equal(st.host_table()[st._id2slot[7]],
                                      2 * row)
        assert 3 not in set(st.live_ids().tolist())

    def test_snapshot_rebuild_is_byte_identical(self):
        st = DynamicTableStore(_table(), block=_BLOCK)
        st.delete(0)
        st.append(np.ones(_DIM, np.float32))
        st.flush_updates()
        rows, ids = st.snapshot()
        fresh = DynamicTableStore(rows, ids=ids, capacity=st.capacity_rows,
                                  block=_BLOCK)
        np.testing.assert_array_equal(st.host_table(), fresh.host_table())
        np.testing.assert_array_equal(st.live_ids(), fresh.live_ids())

    def test_capacity_overflow_raises(self):
        st = DynamicTableStore(_table(n=8), capacity=8, block=_BLOCK)
        st.append(np.zeros(_DIM, np.float32))
        with pytest.raises(RuntimeError, match="store full"):
            st.flush_updates()

    def test_grow_reallocates(self):
        st = DynamicTableStore(_table(n=8), capacity=8, block=_BLOCK)
        st.grow(32)
        assert st.capacity_rows == 32
        for _ in range(20):
            st.append(np.zeros(_DIM, np.float32))
        st.flush_updates()
        assert st.n_live == 28

    def test_engine_survives_grow(self):
        rng = np.random.default_rng(7)
        st = DynamicTableStore(_table(n=24), capacity=24, block=_BLOCK)
        eng = _engine(st)
        q = rng.normal(size=_DIM).astype(np.float32)
        _query(st, eng, q)
        st.grow(64)                       # out-of-band shape change
        winner_id = st.append(
            (9.0 * q / np.linalg.norm(q)).astype(np.float32))
        ids, _ = _query(st, eng, q)       # engine rebuilds its plan
        assert eng.n == st.capacity_rows == 64
        assert winner_id in ids.tolist()
        assert eng.stats()["updates"]["recalibrations"] >= 1

    def test_delete_unknown_raises(self):
        st = DynamicTableStore(_table(n=8), block=_BLOCK)
        st.delete(123)
        with pytest.raises(KeyError, match="unknown id"):
            st.flush_updates()

    def test_failed_flush_is_not_torn(self):
        """A failing mid-batch op drops only itself: successors stay
        staged and the int8 shadow stays in sync with what applied."""
        st = DynamicTableStore(_table(), block=_BLOCK, precision="int8")
        st.upsert(0, np.ones(_DIM, np.float32))
        st.delete(12345)                      # unknown: fails at apply
        st.upsert(1, 2 * np.ones(_DIM, np.float32))
        with pytest.raises(KeyError, match="unknown id"):
            st.flush_updates()
        assert st.pending_updates == 1        # the successor survived
        st.flush_updates()
        assert np.all(st.host_table()[st._id2slot[1]] == 2.0)
        rows, ids = st.snapshot()
        fresh = DynamicTableStore(rows, ids=ids, capacity=st.capacity_rows,
                                  block=_BLOCK, precision="int8")
        np.testing.assert_array_equal(st.host_table(), fresh.host_table())
        V8a, va = st.quantized()
        V8b, vb = fresh.quantized()
        np.testing.assert_array_equal(np.asarray(V8a), np.asarray(V8b))
        np.testing.assert_array_equal(np.asarray(va), np.asarray(vb))

    def test_bad_row_shape_raises(self):
        st = DynamicTableStore(_table(n=8), block=_BLOCK)
        with pytest.raises(ValueError, match="row shape"):
            st.upsert(0, np.zeros(_DIM + 1, np.float32))

    def test_pq_shadow_rejects_non_row_pull_mode(self):
        """Mirror of the PR-7 int8 shadow rule for the pq tier: the
        store's codes are encoded at the store's (tile, block) cells, so
        a coord/hybrid plan (re-blocked feature axis) cannot be served
        from the shadow — and the refusal must be actionable."""
        st = DynamicTableStore(_table(), block=_BLOCK, precision="pq")
        for mode in ("coord", "hybrid"):
            with pytest.raises(ValueError, match="store shadow"):
                _engine(st, pull_mode=mode)
        eng = _engine(st)                  # row mode serves fine
        ids, _ = _query(st, eng, np.ones(_DIM, np.float32))
        assert ids.shape == (_K,)

    def test_refresh_codebook_is_the_one_recalibrating_mutation(self):
        """Dirty tiles re-encode against the *frozen* codebook;
        `refresh_codebook` is the only mutation that retrains it — and
        afterwards the store equals a fresh build (which trains on the
        same bytes) without needing codebook injection."""
        rng = np.random.default_rng(8)
        st = DynamicTableStore(_table(), block=_BLOCK, precision="pq")
        cb0 = np.asarray(st.codebook()).copy()
        for i in range(6):                 # drift the row distribution
            st.upsert(i, (3.0 * rng.normal(size=_DIM)).astype(np.float32))
        st.flush_updates()
        np.testing.assert_array_equal(np.asarray(st.codebook()), cb0)
        v0 = st.version
        info = st.refresh_codebook()
        assert info["refreshes"] == st.codebook_refreshes == 1
        assert st.version == v0 + 1        # engines recalibrate on this
        assert not np.array_equal(np.asarray(st.codebook()), cb0)
        rows, ids = st.snapshot()
        fresh = DynamicTableStore(rows, ids=ids, capacity=st.capacity_rows,
                                  block=_BLOCK, precision="pq")
        ca, cba = st.quantized()
        cf, cbf = fresh.quantized()
        np.testing.assert_array_equal(np.asarray(ca), np.asarray(cf))
        np.testing.assert_array_equal(np.asarray(cba), np.asarray(cbf))

    def test_refresh_codebook_requires_pq(self):
        st = DynamicTableStore(_table(n=8), block=_BLOCK, precision="int8")
        with pytest.raises(RuntimeError, match="pq"):
            st.refresh_codebook()


class TestDeletedNeverReturned:
    """Property-style: across random interleavings, a dead id never comes
    back.  All-negative tables make this adversarial — a zeroed tombstone
    row (score 0) would beat every live arm, so only in-cascade masking
    of the dead suffix can pass."""

    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_random_interleaving(self, seed):
        rng = np.random.default_rng(seed)
        V = -np.abs(rng.normal(size=(96, _DIM))).astype(np.float32)
        st = DynamicTableStore(V, block=_BLOCK, capacity_slack=2.0)
        eng = _engine(st, recall_sample_rate=1.0)
        dead = set()
        for step in range(12):
            live = st.live_ids()
            op = rng.integers(0, 3)
            if op == 0 and live.size > _K + 4:
                victim = int(rng.choice(live))
                st.delete(victim)
                dead.add(victim)
            elif op == 1 and st.free_rows > 0:
                st.append(
                    -np.abs(rng.normal(size=_DIM)).astype(np.float32))
            else:
                tgt = int(rng.choice(live))
                st.upsert(
                    tgt, -np.abs(rng.normal(size=_DIM)).astype(np.float32))
            q = np.abs(rng.normal(size=_DIM)).astype(np.float32)
            ids, scores = _query(st, eng, q)
            got = set(ids.tolist())
            assert not (got & dead), f"dead id returned at step {step}"
            t_ids, t_scores = _masked_truth(st, q)
            assert got == set(t_ids.tolist())
        assert eng.stats()["recall"]["mean"] == 1.0


class TestBitIdentity:
    """The acceptance script: after every mutation step the dynamic
    store/engine is equivalent to a fresh build on its snapshot."""

    def _script(self, st, rng, step, protect=(), scale=1.0):
        live = [i for i in st.live_ids().tolist() if i not in protect]
        row = (scale * rng.normal(size=_DIM)).astype(np.float32)
        if step % 3 == 0:
            st.upsert(int(rng.choice(live)), row)
        elif step % 3 == 1 and st.free_rows > 0:
            st.delete(int(rng.choice(live)))
            st.append(row)
        else:
            st.append(row)
        st.flush_updates()

    @pytest.mark.parametrize("precision", ["fp32", "int8", "int4", "pq"])
    def test_decode_bit_identical_to_fresh_every_step(self, precision):
        rng = np.random.default_rng(3)
        st = DynamicTableStore(_table(), block=_BLOCK, capacity_slack=1.6,
                               precision=precision)
        plan = make_plan(st.capacity_rows, _DIM, K=_K, eps=1e-3, delta=0.05,
                         value_range=16.0, block=_BLOCK, precision=precision,
                         quant_err=0.05 if precision == "pq" else None)
        key = jax.random.PRNGKey(9)
        Q = rng.normal(size=(2, _DIM)).astype(np.float32)
        for step in range(6):
            self._script(st, rng, step)
            rows, ids = st.snapshot()
            # the documented snapshot recipe: a pq rebuild must inherit
            # the frozen codebook or its codes are a different encoding
            fresh = DynamicTableStore(
                rows, ids=ids, capacity=st.capacity_rows, block=_BLOCK,
                precision=precision,
                codebook=st.codebook() if precision == "pq" else None)
            np.testing.assert_array_equal(st.host_table(),
                                          fresh.host_table())
            if precision != "fp32":
                # dirty-tile incremental re-encode == full rebuild,
                # bytewise — int8/int4 (codes, scales) and pq (codes,
                # codebook) alike
                Vqa, auxa = st.quantized()
                Vqb, auxb = fresh.quantized()
                np.testing.assert_array_equal(np.asarray(Vqa),
                                              np.asarray(Vqb))
                np.testing.assert_array_equal(np.asarray(auxa),
                                              np.asarray(auxb))
            kw = dict(plan=plan, final_exact=True, use_pallas=False,
                      n_valid=np.int32(st.n_live))
            ia, sa = bounded_me_decode(st.device_table(), Q, key,
                                       quantized=st.quantized(), **kw)
            ib, sb = bounded_me_decode(fresh.device_table(), Q, key,
                                       quantized=fresh.quantized(), **kw)
            np.testing.assert_array_equal(np.asarray(ia), np.asarray(ib))
            np.testing.assert_array_equal(np.asarray(sa), np.asarray(sb))

    @pytest.mark.parametrize("precision", ["fp32", "int8", "int4", "pq"])
    def test_engine_matches_fresh_engine_after_burst(self, precision):
        rng = np.random.default_rng(4)
        st = DynamicTableStore(_table(scale=0.2), block=_BLOCK,
                               capacity_slack=1.6, precision=precision)
        # pq: pin quant_err so the fresh engine (which would otherwise
        # re-measure on the post-burst table) builds the identical plan
        ekw = {"quant_err": 0.05} if precision == "pq" else {}
        eng = _engine(st, eps=1e-3, **ekw)
        qs = rng.normal(size=(3, _DIM)).astype(np.float32)
        planted = []
        for b, q in enumerate(qs):       # planted winners: margins >> the
            unit = q / np.linalg.norm(q)  # int8 bias, so fp32 and int8
            for j in range(_K):           # agree on the exact top-K
                st.upsert(17 * b + 5 * j + 1,
                          ((4.0 + 0.5 * j) * unit).astype(np.float32))
                planted.append(17 * b + 5 * j + 1)
        for step in range(4):
            self._script(st, rng, step, protect=planted, scale=0.2)
            rows, ids = st.snapshot()
            fresh_store = DynamicTableStore(
                rows, ids=ids, capacity=st.capacity_rows, block=_BLOCK,
                precision=precision,
                codebook=st.codebook() if precision == "pq" else None)
            fresh = _engine(fresh_store, eps=1e-3, **ekw)
            for q in qs:
                ia, sa = _query(st, eng, q)
                ib, sb = _query(fresh_store, fresh, q)
                np.testing.assert_array_equal(ia, ib)
                np.testing.assert_array_equal(sa, sb)


class TestZeroRecompilation:
    @pytest.mark.parametrize("precision", ["fp32", "int8", "int4", "pq"])
    def test_mutation_stream_compiles_nothing_new(self, precision):
        rng = np.random.default_rng(5)
        st = DynamicTableStore(_table(), block=_BLOCK, capacity_slack=2.0,
                               precision=precision)
        eng = _engine(st, eps=1e-3)
        # warmup: touch every op class once (first compile is expected)
        st.upsert(0, rng.normal(size=_DIM).astype(np.float32))
        st.delete(1)
        st.append(rng.normal(size=_DIM).astype(np.float32))
        _query(st, eng, rng.normal(size=_DIM).astype(np.float32))
        before = (eng._fn._cache_size(), st.jit_cache_size())
        for step in range(24):
            live = st.live_ids()
            op = step % 3
            if op == 0:
                st.upsert(int(rng.choice(live)),
                          rng.normal(size=_DIM).astype(np.float32))
            elif op == 1 and st.free_rows > 0:
                st.delete(int(rng.choice(live)))
                st.append(rng.normal(size=_DIM).astype(np.float32))
            else:
                st.append(rng.normal(size=_DIM).astype(np.float32))
            _query(st, eng, rng.normal(size=_DIM).astype(np.float32))
        after = (eng._fn._cache_size(), st.jit_cache_size())
        assert after == before, (
            f"mutation stream recompiled: {before} -> {after}")
        assert eng.stats()["updates"]["recalibrations"] == 0


class TestValueRangeTracking:
    def test_growth_recalibrates_once_and_stays_correct(self):
        rng = np.random.default_rng(6)
        st = DynamicTableStore(_table(), block=_BLOCK, capacity_slack=1.5)
        eng = _engine(st, value_range=None, recall_sample_rate=1.0)
        vr0 = eng._plan_value_range
        q = rng.normal(size=_DIM).astype(np.float32)
        big = (40.0 * q / np.linalg.norm(q)).astype(np.float32)
        gid = st.append(big)
        ids, _ = _query(st, eng, q)
        assert gid in ids.tolist()
        assert eng.stats()["updates"]["recalibrations"] == 1
        assert eng._plan_value_range > vr0
        # a second in-range update must not recalibrate again
        st.upsert(0, rng.normal(size=_DIM).astype(np.float32))
        _query(st, eng, q)
        assert eng.stats()["updates"]["recalibrations"] == 1
        assert eng.stats()["recall"]["mean"] == 1.0


# ---------------------------------------------------------------------------
# 2-device ShardedTableStore suite (subprocess, fake CPU devices)
# ---------------------------------------------------------------------------

_ENV_CODE_PREAMBLE = r"""
import os
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=2").strip()
import jax, jax.numpy as jnp, numpy as np
"""


def _run(code: str, timeout=480):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    r = subprocess.run([sys.executable, "-c", _ENV_CODE_PREAMBLE + code],
                       env=env, capture_output=True, text=True,
                       timeout=timeout)
    assert "OK" in r.stdout, r.stdout + "\n" + r.stderr


@pytest.mark.slow
def test_sharded_store_decode_matches_fresh_and_truth():
    """Per-shard n_valid vector: churned store == fresh buffer, bitwise,
    and == live-masked exact truth; dead ids never returned."""
    _run(r"""
from repro.distributed.sharding import sharded_bounded_me_decode
from repro.store import ShardedTableStore
mesh = jax.make_mesh((2,), ("model",))
rng = np.random.default_rng(0)
n, N, B, K = 300, 256, 2, 3
V = -np.abs(rng.normal(size=(n, N))).astype(np.float32)   # adversarial
st = ShardedTableStore(V, mesh=mesh, block=128, capacity_slack=1.5)
dead = set()
for step in range(6):
    live = st.live_ids()
    victim = int(rng.choice(live))
    st.delete(victim); dead.add(victim)
    nid = st.append(-np.abs(rng.normal(size=N)).astype(np.float32))
    st.upsert(int(rng.choice(st.live_ids())),
              -np.abs(rng.normal(size=N)).astype(np.float32))
    st.flush_updates()
    Q = jnp.asarray(np.abs(rng.normal(size=(B, N))), jnp.float32)
    key = jax.random.PRNGKey(step)
    kw = dict(mesh=mesh, K=K, eps=1e-4, delta=0.05, value_range=16.0,
              block=128, n_valid=st.n_valid_vector())
    i1, s1, _ = sharded_bounded_me_decode(st.device_table(), Q, key, **kw)
    # fresh device buffer with identical bytes -> bit-identical output
    fresh = jnp.asarray(st.host_table().copy())
    i2, s2, _ = sharded_bounded_me_decode(fresh, Q, key, **kw)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    ext = st.external_ids(np.asarray(i1))
    assert not (set(ext.ravel().tolist()) & dead), step
    H = st.host_table().copy()
    S = H @ np.asarray(Q).T
    S[~st.live_mask()] = -np.inf
    truth = np.argsort(-S, axis=0)[:K].T
    np.testing.assert_array_equal(np.asarray(i1), truth)
print("OK")
""")


@pytest.mark.slow
@pytest.mark.parametrize("precision", ["fp32", "int8"])
def test_sharded_store_engine_after_burst(precision):
    """2-device engine on a ShardedTableStore: upsert burst, then exact
    recall and zero recompiles (per-shard live counts ride traced)."""
    _run(r"""
from repro.launch.serve import MIPSServeEngine
from repro.store import ShardedTableStore
mesh = jax.make_mesh((2,), ("model",))
rng = np.random.default_rng(1)
n, N, K = 300, 256, 3
V = (0.2 * rng.normal(size=(n, N))).astype(np.float32)
st = ShardedTableStore(V, mesh=mesh, block=128, capacity_slack=1.6)
eng = MIPSServeEngine(st, K=K, eps=1e-3, delta=0.05, value_range=16.0,
                      batch_size=2, deadline_ms=1.0,
                      recall_sample_rate=1.0, precision=%r)
def query(q):
    rid = eng.submit(q, now=float(eng.n_requests))
    eng.drain(now=float(eng.n_requests))
    return eng.result(rid)
qs = rng.normal(size=(3, N)).astype(np.float32)
planted = {}
for b, q in enumerate(qs):               # margins >> int8 bias
    unit = q / np.linalg.norm(q)
    for j in range(K):
        nid = st.append(((4.0 + 0.5 * j) * unit).astype(np.float32))
        planted.setdefault(b, []).append(nid)
query(qs[0])                             # warmup + drain the burst
before = eng._fn._cache_size() + st.jit_cache_size()
for step in range(8):
    st.delete(int(rng.choice([i for i in st.live_ids()
                              if i not in sum(planted.values(), [])])))
    st.append((0.2 * rng.normal(size=N)).astype(np.float32))
    for b, q in enumerate(qs):
        ids, scores = query(q)
        assert set(ids.tolist()) == set(planted[b]), (step, b)
after = eng._fn._cache_size() + st.jit_cache_size()
assert after == before, (before, after)
assert eng.stats()["recall"]["mean"] == 1.0
print("OK")
""" % precision)
