"""Blocked/tiled jitted BoundedME: correctness vs exact, pallas parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.core import bounded_me_blocked, bounded_me_batched, make_plan


def _data(n, N, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(n, N)).astype(np.float32),
            rng.normal(size=N).astype(np.float32))


class TestBlocked:
    def test_exact_recovery_small_eps(self):
        V, q = _data(2048, 4096)
        ids, scores, plan = bounded_me_blocked(
            V, q, jax.random.PRNGKey(0), K=5, eps=1e-4, delta=0.05,
            value_range=8.0, block=256, final_exact=True)
        true = np.argsort(-(V @ q))[:5]
        assert set(np.asarray(ids).tolist()) == set(true.tolist())

    def test_score_estimates_mean_product(self):
        V, q = _data(512, 2048, seed=1)
        ids, scores, _ = bounded_me_blocked(
            V, q, jax.random.PRNGKey(1), K=3, eps=1e-4, delta=0.05,
            value_range=8.0, final_exact=True)
        for i, s in zip(np.asarray(ids), np.asarray(scores)):
            assert abs(s - float(V[i] @ q) / V.shape[1]) < 1e-3

    def test_plan_flop_accounting(self):
        plan = make_plan(10_000, 100_000, K=1, eps=0.3, delta=0.1,
                         value_range=1.0, block=512)
        assert plan.total_multiplies <= plan.naive_multiplies
        assert plan.speedup >= 1.0

    @given(st.integers(9, 600), st.integers(65, 3000), st.integers(1, 6))
    @settings(max_examples=12, deadline=None)
    def test_ragged_shapes_no_crash(self, n, N, K):
        """Property: arbitrary (non-multiple) n, N, K are handled by padding."""
        V, q = _data(n, N, seed=n + N)
        ids, scores, _ = bounded_me_blocked(
            V, q, jax.random.PRNGKey(2), K=min(K, n), eps=0.2, delta=0.2,
            value_range=8.0, tile=8, block=64, final_exact=True)
        ids = np.asarray(ids)
        assert ids.shape[0] == min(K, n)
        assert (0 <= ids).all() and (ids < n).all()
        assert len(set(ids.tolist())) == ids.shape[0]  # no padded dupes

    def test_top1_quality_moderate_eps(self):
        V, q = _data(4096, 16384, seed=2)
        hits = 0
        for s in range(5):
            ids, _, _ = bounded_me_blocked(
                V, q, jax.random.PRNGKey(s), K=1, eps=0.4, delta=0.1,
                value_range=8.0, final_exact=True)
            hits += int(ids[0]) == int(np.argmax(V @ q))
        assert hits >= 4  # eps=0.4 @ delta=0.1 should nearly always get top-1

    def test_batched_matches_single(self):
        V, q = _data(1024, 2048, seed=3)
        Q = np.stack([q, -q, q * 0.5])
        plan = make_plan(1024, 2048, K=2, eps=0.1, delta=0.1,
                         value_range=8.0)
        keys = jax.random.split(jax.random.PRNGKey(0), 3)
        ids_b, scores_b = bounded_me_batched(V, Q, keys, plan=plan,
                                             final_exact=True)
        for i in range(3):
            ids_s, scores_s, _ = bounded_me_blocked(
                V, Q[i], keys[i], plan=plan, final_exact=True)
            assert np.array_equal(np.asarray(ids_b[i]), np.asarray(ids_s))


class TestPallasParity:
    @pytest.mark.parametrize("block,tile", [(128, 8), (256, 8), (64, 4)])
    def test_pallas_equals_einsum_path(self, block, tile):
        V, q = _data(512, 2048, seed=4)
        kw = dict(K=3, eps=0.3, delta=0.1, value_range=8.0, tile=tile,
                  block=block)
        i1, s1, _ = bounded_me_blocked(V, q, jax.random.PRNGKey(7),
                                       use_pallas=True, **kw)
        i2, s2, _ = bounded_me_blocked(V, q, jax.random.PRNGKey(7),
                                       use_pallas=False, **kw)
        assert np.array_equal(np.asarray(i1), np.asarray(i2))
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-5)
