"""FlatSchedule invariants: the flattening consumed by the fused kernel."""

import numpy as np
import pytest

from repro.core.schedule import (END_BIT, PULL_BIT, SLOT_MASK,
                                 flatten_schedule, make_schedule)


def _flat(n=50, N=40, K=2, eps=0.2, delta=0.1, **kw):
    return make_schedule(n, N, K=K, eps=eps, delta=delta), kw


@pytest.mark.parametrize("n,N,K,eps", [(50, 40, 2, 0.2), (400, 4, 1, 0.05),
                                       (7, 100, 3, 0.4), (64, 64, 8, 0.1)])
def test_flatten_invariants(n, N, K, eps):
    sched = make_schedule(n, N, K=K, eps=eps, delta=0.1)
    flat = flatten_schedule(sched)
    # one end flag per round, in order
    assert int(flat.is_end.sum()) == len(sched.rounds)
    # pull steps count = total sample complexity of the schedule
    assert int(flat.is_pull.sum()) == sched.total_pulls
    # slots stay inside the round's survivor count
    assert (flat.slot < flat.n_surv).all()
    # block positions stay inside the reward list
    assert (flat.bpos >= 0).all() and (flat.bpos < N).all()
    # survivor counts per round follow the elimination chain
    ends = np.nonzero(flat.is_end)[0]
    for j, r in zip(ends, sched.rounds):
        assert flat.n_surv[j] == r.n_arms
        assert flat.n_keep[j] == r.n_keep
        assert flat.t_cum[j] == r.t_cum
    assert flat.n_final == (sched.rounds[-1].n_keep if sched.rounds
                            else sched.n)
    assert flat.t_final == (sched.rounds[-1].t_cum if sched.rounds else 0)


def test_flatten_saturated_round_emits_noop_end_step():
    sched = make_schedule(400, 4, K=1, eps=0.05, delta=0.1)
    assert any(r.t_new == 0 for r in sched.rounds)
    flat = flatten_schedule(sched)
    noop_ends = (flat.is_pull == 0) & (flat.is_end == 1)
    assert noop_ends.sum() == sum(r.t_new == 0 for r in sched.rounds)


def test_flatten_final_coverage_completes_to_N():
    sched = make_schedule(64, 32, K=2, eps=0.3, delta=0.1)
    flat = flatten_schedule(sched, final_coverage=True)
    assert flat.t_final == sched.N
    # coverage pulls touch every survivor for every remaining block
    extra = flat.n_steps - flatten_schedule(sched).n_steps
    t_last = sched.rounds[-1].t_cum
    assert extra == (sched.N - t_last) * flat.n_final


def test_flatten_degenerate_no_rounds():
    sched = make_schedule(8, 16, K=8)          # K >= n: nothing to eliminate
    assert not sched.rounds
    flat = flatten_schedule(sched)
    assert flat.n_steps == 1                   # single no-op finalize step
    assert int(flat.is_pull.sum()) == 0 and int(flat.is_end.sum()) == 0


def test_packed_roundtrip():
    sched = make_schedule(50, 40, K=2, eps=0.2, delta=0.1)
    flat = flatten_schedule(sched, final_coverage=True)
    code, meta = flat.packed()
    assert code.dtype == np.int32 and meta.dtype == np.int32
    np.testing.assert_array_equal(code & SLOT_MASK, flat.slot)
    np.testing.assert_array_equal((code & PULL_BIT) != 0, flat.is_pull == 1)
    np.testing.assert_array_equal((code & END_BIT) != 0, flat.is_end == 1)
    assert meta.shape == (len(sched.rounds) + 1, 3)
    for j, r in enumerate(sched.rounds):
        assert tuple(meta[j]) == (r.t_cum, r.n_arms, r.n_keep)
