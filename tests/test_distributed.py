"""Distributed (shard_map) paths vs single-device references.

Run on 8 fake CPU devices in a subprocess so the main pytest process keeps
its 1-device view (per the dry-run isolation rule).
"""

import os
import subprocess
import sys

import pytest

_ENV_CODE_PREAMBLE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
"""


def _run(code: str, timeout=480):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    r = subprocess.run([sys.executable, "-c", _ENV_CODE_PREAMBLE + code],
                       env=env, capture_output=True, text=True,
                       timeout=timeout)
    assert "OK" in r.stdout, r.stdout + "\n" + r.stderr


@pytest.mark.slow
def test_sharded_mips_matches_exact():
    _run(r"""
from repro.core.mips import sharded_mips_topk
mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
n, N, B, K = 1024, 1024, 4, 3
table = jnp.asarray(rng.normal(size=(n, N)), jnp.float32)
Q = jnp.asarray(rng.normal(size=(B, N)), jnp.float32)
keys = jax.random.split(jax.random.PRNGKey(0), B)
ids, scores = jax.jit(lambda t, q, k: sharded_mips_topk(
    t, q, k, K=K, mesh=mesh, batch_axes="data", eps=1e-4, delta=0.05,
    value_range=8.0, block=128, final_exact=True))(table, Q, keys)
truth = np.argsort(-(np.asarray(table) @ np.asarray(Q).T), axis=0)[:K].T
for b in range(B):
    assert set(np.asarray(ids)[b].tolist()) == set(truth[b].tolist()), b
print("OK")
""")


@pytest.mark.slow
def test_sharded_mips_masks_padded_vocab():
    _run(r"""
from repro.core.mips import sharded_mips_topk
mesh = jax.make_mesh((1, 8), ("data", "model"))
rng = np.random.default_rng(1)
n, n_valid, N = 1024, 900, 512
table = jnp.asarray(-np.abs(rng.normal(size=(n, N))), jnp.float32)
table = table.at[n_valid:].set(0.0)       # zero pad rows would win (score 0)
Q = jnp.asarray(np.abs(rng.normal(size=(2, N))), jnp.float32)
keys = jax.random.split(jax.random.PRNGKey(0), 2)
ids, _ = jax.jit(lambda t, q, k: sharded_mips_topk(
    t, q, k, K=2, mesh=mesh, batch_axes=None, n_valid=n_valid, eps=1e-4,
    delta=0.05, value_range=8.0, block=128, final_exact=True))(table, Q, keys)
assert int(np.asarray(ids).max()) < n_valid, np.asarray(ids)
print("OK")
""")


@pytest.mark.slow
def test_ep_moe_matches_fallback():
    _run(r"""
import dataclasses
from repro.configs import REGISTRY
from repro.distributed.sharding import logical_mesh
from repro.models import layers as L
from repro.models.model import init_params
cfg = dataclasses.replace(REGISTRY["qwen3-moe-30b-a3b"].smoke(),
                          capacity_factor=16.0)
mesh = jax.make_mesh((2, 4), ("data", "model"))
params = init_params(cfg, jax.random.PRNGKey(0))
lp = {k: v[0] for k, v in params["layers"].items()
      if k in ("router", "w_gate", "w_up", "w_down")}
x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, cfg.d_model),
                      jnp.float32)
y_ref = L.moe_layer(x, lp, cfg)       # no mesh bound: GSPMD/vmapped path
with logical_mesh(mesh):
    y_ep = jax.jit(lambda x, lp: L.moe_layer(x, lp, cfg))(x, lp)
err = float(jnp.abs(y_ref - y_ep).max() / (jnp.abs(y_ref).max() + 1e-9))
assert err < 2e-5, err
print("OK")
""")


@pytest.mark.slow
def test_boundedme_decode_sharded_vs_exact():
    """decode_step with vocab-sharded table + shard_map bandit == exact."""
    _run(r"""
import dataclasses
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import REGISTRY
from repro.distributed.sharding import logical_mesh
from repro.distributed.specs import param_pspecs
from repro.models.model import init_params
from repro.models.steps import decode_step, prefill_step
cfg = dataclasses.replace(REGISTRY["qwen1.5-0.5b"].smoke(), vocab_pad=64,
                          mips_eps=0.01)
mesh = jax.make_mesh((2, 4), ("data", "model"))
params = init_params(cfg, jax.random.PRNGKey(0))
tok = jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab, (4, 8)),
                  jnp.int32)
with logical_mesh(mesh):
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                       param_pspecs(cfg, params, mesh))
    params = jax.device_put(params, psh)
    _, caches = prefill_step(params, cfg, tok, cache_len=16)
    cfg_b = dataclasses.replace(cfg, mips_mode="boundedme")
    cfg_e = dataclasses.replace(cfg, mips_mode="exact")
    tb, _ = jax.jit(lambda p, c, t: decode_step(
        p, cfg_b, c, t, jnp.int32(8), key=jax.random.PRNGKey(1)))(
        params, caches, tok[:, -1:])
    te, _ = jax.jit(lambda p, c, t: decode_step(
        p, cfg_e, c, t, jnp.int32(8)))(params, caches, tok[:, -1:])
assert np.array_equal(np.asarray(tb), np.asarray(te)), (tb, te)
print("OK")
""")
