"""Fused-path BoundedME and the batched decode path (no hypothesis dep).

Covers the PR-1 acceptance criteria that must run from a clean checkout:
bitwise fused-vs-fallback parity, batched-vs-loop equivalence, the K > tile
adversarial-placement regression, and the final_exact rescale fix for
ragged N.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.boundedme_jax import (bounded_me_batched, bounded_me_blocked,
                                      bounded_me_decode, make_plan)


def _data(n, N, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(n, N)).astype(np.float32),
            rng.normal(size=N).astype(np.float32))


class TestFusedPath:
    @pytest.mark.parametrize("n,N,tile,block,K", [
        (512, 2048, 8, 128, 3),
        (517, 2100, 8, 256, 12),     # ragged + K > tile
        (123, 300, 8, 64, 5),
    ])
    def test_fused_matches_fallback_bitwise(self, n, N, tile, block, K):
        """Same PRNG key => identical ids AND bit-identical scores: the
        kernel accumulates blocks in the exact order of the scan fallback."""
        V, q = _data(n, N, seed=n)
        kw = dict(K=K, eps=0.25, delta=0.1, value_range=8.0, tile=tile,
                  block=block)
        i_f, s_f, _ = bounded_me_blocked(V, q, jax.random.PRNGKey(7),
                                         use_pallas=True, **kw)
        i_j, s_j, _ = bounded_me_blocked(V, q, jax.random.PRNGKey(7),
                                         use_pallas=False, **kw)
        np.testing.assert_array_equal(np.asarray(i_f), np.asarray(i_j))
        np.testing.assert_array_equal(np.asarray(s_f), np.asarray(s_j))

    def test_fused_final_exact_allclose(self):
        V, q = _data(517, 2100, seed=2)
        kw = dict(K=4, eps=0.2, delta=0.1, value_range=8.0, block=256,
                  final_exact=True)
        i_f, s_f, _ = bounded_me_blocked(V, q, jax.random.PRNGKey(3),
                                         use_pallas=True, **kw)
        i_j, s_j, _ = bounded_me_blocked(V, q, jax.random.PRNGKey(3),
                                         use_pallas=False, **kw)
        np.testing.assert_array_equal(np.asarray(i_f), np.asarray(i_j))
        np.testing.assert_allclose(np.asarray(s_f), np.asarray(s_j),
                                   rtol=2e-5, atol=1e-6)

    def test_batched_fused_matches_loop(self):
        V, q = _data(300, 900, seed=4)
        Q = np.stack([q, -q, 0.5 * q])
        plan = make_plan(300, 900, K=2, eps=0.2, delta=0.1, value_range=8.0,
                         block=64)
        keys = jax.random.split(jax.random.PRNGKey(0), 3)
        ids_b, sc_b = bounded_me_batched(V, Q, keys, plan=plan,
                                         use_pallas=True)
        for b in range(3):
            ids_s, sc_s, _ = bounded_me_blocked(V, Q[b], keys[b], plan=plan,
                                                use_pallas=True)
            np.testing.assert_array_equal(np.asarray(ids_b[b]),
                                          np.asarray(ids_s))
            np.testing.assert_array_equal(np.asarray(sc_b[b]),
                                          np.asarray(sc_s))


class TestDecodeBatched:
    def test_pallas_and_jnp_decode_agree(self):
        V, q = _data(256, 1024, seed=5)
        Q = np.stack([q, -q, 0.3 * q, _data(1, 1024, seed=9)[1]])
        plan = make_plan(256, 1024, K=2, eps=0.2, delta=0.1, value_range=8.0,
                         block=128)
        key = jax.random.PRNGKey(11)
        ids_p, sc_p = bounded_me_decode(V, Q, key, plan=plan,
                                        final_exact=False, use_pallas=True)
        ids_j, sc_j = bounded_me_decode(V, Q, key, plan=plan,
                                        final_exact=False, use_pallas=False)
        np.testing.assert_array_equal(np.asarray(ids_p), np.asarray(ids_j))
        np.testing.assert_array_equal(np.asarray(sc_p), np.asarray(sc_j))

    def test_decode_recovers_exact_topk_small_eps(self):
        V, q = _data(1024, 2048, seed=6)
        B = 5
        rng = np.random.default_rng(7)
        Q = rng.normal(size=(B, 2048)).astype(np.float32)
        K = 3
        plan = make_plan(1024, 2048, K=K, eps=1e-4, delta=0.05,
                         value_range=8.0, block=256)
        ids, scores = bounded_me_decode(V, Q, jax.random.PRNGKey(0),
                                        plan=plan, final_exact=True,
                                        use_pallas=False)
        truth = np.argsort(-(V @ Q.T), axis=0)[:K].T
        for b in range(B):
            assert (set(np.asarray(ids)[b].tolist())
                    == set(truth[b].tolist())), b

    def test_decode_scores_estimate_mean_product_ragged(self):
        """final_exact scores must estimate (q.v)/N even when N % block != 0
        (regression: the rescale used to be applied twice on this path)."""
        V, q = _data(200, 1000, seed=8)          # 1000 % 256 != 0
        Q = np.stack([q, -0.5 * q])
        plan = make_plan(200, 1000, K=2, eps=1e-4, delta=0.05,
                         value_range=8.0, block=256)
        ids, scores = bounded_me_decode(V, Q, jax.random.PRNGKey(1),
                                        plan=plan, final_exact=True,
                                        use_pallas=False)
        for b in range(2):
            for i, s in zip(np.asarray(ids)[b], np.asarray(scores)[b]):
                assert abs(s - float(V[i] @ Q[b]) / 1000.0) < 1e-5

    def test_single_query_final_exact_scores_ragged(self):
        """Same regression on the single-query path, fused and fallback."""
        V, q = _data(200, 1000, seed=12)
        for use_pallas in (False, True):
            ids, scores, _ = bounded_me_blocked(
                V, q, jax.random.PRNGKey(2), K=3, eps=1e-4, delta=0.05,
                value_range=8.0, block=256, final_exact=True,
                use_pallas=use_pallas)
            for i, s in zip(np.asarray(ids), np.asarray(scores)):
                assert abs(s - float(V[i] @ q) / 1000.0) < 1e-5, use_pallas


class TestKTilesRegression:
    def test_k_tiles_is_min_n_tiles_K(self):
        plan = make_plan(128, 512, K=12, tile=8, block=64)
        assert plan.k_tiles == 12            # NOT ceil(K/tile) == 2
        plan = make_plan(16, 512, K=12, tile=8, block=64)
        assert plan.k_tiles == plan.n_tiles  # capped at the tile count

    def test_adversarial_winner_placement_K_gt_tile(self):
        """Top-K arms spread one-per-tile: only min(n_tiles, K) surviving
        tiles can hold them all (ceil(K/tile) tiles would drop winners)."""
        n, N, K, tile = 128, 512, 12, 8
        rng = np.random.default_rng(42)
        V = 0.01 * rng.normal(size=(n, N)).astype(np.float32)
        q = np.ones(N, np.float32)
        # winner i lives in tile i at row i: one winner per tile
        for i in range(K):
            V[i * tile + i % tile] = 1.0 - 0.01 * i
        ids, _, plan = bounded_me_blocked(
            V, q, jax.random.PRNGKey(0), K=K, eps=1e-4, delta=0.05,
            value_range=4.0, tile=tile, block=64, final_exact=True)
        assert plan.k_tiles == K
        expect = {i * tile + i % tile for i in range(K)}
        assert set(np.asarray(ids).tolist()) == expect
